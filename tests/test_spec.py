"""Self-speculative decoding (ISSUE 9): greedy spec output must be
token-identical to vanilla decode across the cache families (paged
attention / recurrent state tables / hybrid), including with
temperature-1.0 drafts that force rejections (rollback + state replay),
mid-speculation preemption/restore, and prefix-cache-warm starts.  The
acceptance rules are unit-tested directly, including the exact
rejection-sampling rule's emitted-marginal guarantee, and the draft-cap
plan tree is checked treedef-stable (sweeping the cap never recompiles).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import get_model
from repro.serving import Engine
from repro.serving.spec import accept_greedy, accept_sampled, emit_matrix


def _params(arch, chunk=None):
    cfg = reduce_config(get_config(arch))
    if chunk:
        cfg = cfg.replace(serve_chunk=chunk)
    api = get_model(cfg)
    return cfg, api.init(jax.random.PRNGKey(0), cfg)


def _reqs(cfg, shapes, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(1, cfg.vocab_size, size=p).astype(np.int32),
             int(g)) for p, g in shapes]


# -- acceptance rules (pure) -----------------------------------------------

def test_accept_greedy_and_emit_matrix():
    """Hand-checked rows: partial accept (correction at the mismatch),
    immediate reject (vanilla-decode degenerate), full accept (bonus),
    and a slot sitting the round out (n_valid = 0 emits nothing)."""
    drafts = jnp.asarray([[5, 6, 7], [1, 2, 3], [9, 8, 7], [0, 0, 0]],
                         jnp.int32)
    targets = jnp.asarray([[5, 6, 8, 4], [7, 1, 2, 3], [9, 8, 7, 2],
                           [3, 1, 1, 1]], jnp.int32)
    k_valid = jnp.asarray([3, 2, 3, 0], jnp.int32)
    n_accept, correction = accept_greedy(drafts, targets, k_valid)
    assert n_accept.tolist() == [2, 0, 3, 0]
    assert correction.tolist() == [8, 7, 2, 3]
    n_valid = jnp.asarray([4, 3, 4, 0], jnp.int32)
    toks, n_emit = emit_matrix(drafts, n_accept, correction, n_valid)
    assert n_emit.tolist() == [3, 1, 4, 0]
    assert toks[0, :3].tolist() == [5, 6, 8]
    assert toks[1, :1].tolist() == [7]
    assert toks[2].tolist() == [9, 8, 7, 2]


def test_accept_sampled_first_token_marginal():
    """The rejection rule's guarantee: drafting from q and verifying
    against p emits a first token distributed EXACTLY as p, for any
    proposal q — measured empirically over many seeded trials."""
    V = 5
    p = jnp.asarray([0.44, 0.26, 0.14, 0.10, 0.06], jnp.float32)
    q = jnp.asarray([0.10, 0.20, 0.30, 0.25, 0.15], jnp.float32)
    tgt = jnp.stack([p, jnp.full((V,), 1.0 / V)])[None]       # (1, 2, V)
    k_valid = jnp.ones((1,), jnp.int32)

    def trial(key):
        kd, ka = jax.random.split(key)
        d = jax.random.categorical(kd, jnp.log(q)).astype(jnp.int32)
        drafts = d[None, None]
        n_acc, corr = accept_sampled(drafts, q[None, None], tgt,
                                     k_valid, ka)
        return jnp.where(n_acc[0] > 0, drafts[0, 0], corr[0])

    N = 8000
    firsts = np.asarray(jax.vmap(trial)(
        jax.random.split(jax.random.PRNGKey(7), N)))
    emp = np.bincount(firsts, minlength=V) / N
    # 5+ sigma at the largest mass: sqrt(.44 * .56 / 8000) ~ 0.0056
    np.testing.assert_allclose(emp, np.asarray(p), atol=0.03)


def test_accept_sampled_point_mass_draft_reduces_to_greedy():
    """A one-hot q (greedy draft under a sampled target) accepts iff the
    target puts ANY mass on the drafted token scaled by u — with p
    concentrated on the draft it always accepts."""
    V = 4
    drafts = jnp.asarray([[2]], jnp.int32)
    q = jax.nn.one_hot(drafts, V, dtype=jnp.float32)
    p_hit = jnp.asarray([[[0.0, 0.0, 1.0, 0.0],
                          [0.25, 0.25, 0.25, 0.25]]], jnp.float32)
    n_acc, _ = accept_sampled(drafts, q, p_hit, jnp.ones((1,), jnp.int32),
                              jax.random.PRNGKey(0))
    assert int(n_acc[0]) == 1
    p_miss = jnp.asarray([[[1.0, 0.0, 0.0, 0.0],
                           [0.25, 0.25, 0.25, 0.25]]], jnp.float32)
    n_acc, corr = accept_sampled(drafts, q, p_miss,
                                 jnp.ones((1,), jnp.int32),
                                 jax.random.PRNGKey(0))
    assert int(n_acc[0]) == 0 and int(corr[0]) == 0


# -- greedy identity across cache families ---------------------------------

@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-3b", "zamba2-7b"])
def test_spec_greedy_token_identity(arch):
    """The tentpole acceptance criterion: speculative greedy output is
    token-identical to vanilla decode — with greedy drafts (full-accept
    fast path) AND with temperature-1.0 drafts, which force rejections
    so the page rollback and (for state families) the replay dispatch
    are exercised while the emitted stream must stay exactly greedy."""
    cfg, params = _params(arch)
    reqs = _reqs(cfg, [(9, 12), (5, 7), (13, 16), (7, 1)])
    want = Engine(cfg, params, n_slots=2, max_len=64,
                  telemetry=False).run(list(reqs))
    for draft_t in (0.0, 1.0):
        eng = Engine(cfg, params, n_slots=2, max_len=64, telemetry=False,
                     spec_k=3, spec_draft_temperature=draft_t)
        got = eng.run(list(reqs))
        assert got == want, f"{arch} draft_t={draft_t}: tokens diverge"
        sp = eng.spec.report()
        assert sp["rounds"] > 0 and sp["tokens_drafted"] > 0
        assert sp["aborts"] == 0
        kinds = eng.scheduler.dispatch_kinds
        assert kinds["draft"] > 0 and kinds["verify"] > 0
        if draft_t == 0.0:
            # dense mode: draft plans == target plans, so greedy drafts
            # are the target argmax (ties under reduction-order noise
            # are the only slack)
            assert sp["acceptance_rate"] >= 0.9, sp
        elif arch != "granite-3-2b":
            # random drafts get rejected; recurrent-state families must
            # take the restore + replay path, not just truncate
            assert sp["replays"] > 0, sp
        rep = eng.report()
        assert rep["spec"]["rounds"] == sp["rounds"]


# -- preemption / restore mid-speculation ----------------------------------

@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-3b"])
def test_spec_preemption_token_identity(arch):
    """Force a spill after speculative rounds have run and let the
    victim resume: rounds are atomic inside Engine.step, so the spill
    reads committed positions/state and every request's greedy tokens
    still match the untouched non-spec twin."""
    cfg, params = _params(arch, chunk=8)
    prompts = [p for p, _ in _reqs(cfg, [(10, 5), (14, 5), (7, 5)],
                                   seed=4)]
    # gen 12 >> k+1: requests survive the first speculative round, so a
    # decoding victim still exists when the preemption fires
    want = Engine(cfg, params, n_slots=2, max_len=48, chunk=8,
                  telemetry=False).run([(p, 12) for p in prompts])
    eng = Engine(cfg, params, n_slots=2, max_len=48, chunk=8,
                 telemetry=False, spec_k=3)
    rids = [eng.submit(p, 12) for p in prompts]
    for _ in range(30):
        eng.step()
        if eng.spec.counters["rounds"] >= 1:
            break
    assert eng.spec.counters["rounds"] >= 1, "no speculative round ran"
    victim = eng.policy.spill_victim(eng.scheduler.slots)
    assert victim is not None
    eng._preempt(victim)
    assert eng.counters["preemptions"] == 1
    while eng.scheduler.has_work:
        eng.step()
    eng.drain()
    assert eng.pool.spill_events["restores"] == 1
    for rid, (_, toks) in zip(rids, sorted(want.items())):
        assert eng.results[rid] == toks, \
            f"{arch}: preemption under speculation changed tokens"


# -- prefix-cache-warm starts ----------------------------------------------

@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-3b"])
def test_spec_prefix_cache_warm_identity(arch):
    """Speculating from a prefix-cache hit (COW forks against published
    pages / restored state snapshots) must not change tokens: the spec
    engine matches vanilla on a shared-prefix trace, and a second fully
    warm pass over the same prompts matches the first."""
    cfg, params = _params(arch, chunk=8)
    rng = np.random.default_rng(1)
    prefix = rng.integers(1, cfg.vocab_size, size=24).astype(np.int32)
    reqs = [(np.concatenate(
        [prefix, rng.integers(1, cfg.vocab_size, size=4).astype(np.int32)]),
        6) for _ in range(3)]
    want = Engine(cfg, params, n_slots=2, max_len=64, chunk=8,
                  telemetry=False).run(list(reqs))
    eng = Engine(cfg, params, n_slots=2, max_len=64, chunk=8,
                 telemetry=False, spec_k=3)
    got1 = eng.run(list(reqs))
    assert got1 == want, f"{arch}: spec diverges on shared-prefix trace"
    hits1 = eng._prefix_counters()["prefix_hits"]
    assert hits1 > 0, "trace never hit the prefix cache"
    got2 = eng.run(list(reqs))
    assert [got2[r] for r in sorted(got2)] == \
        [got1[r] for r in sorted(got1)], \
        f"{arch}: warm-start speculation diverges"
    assert eng._prefix_counters()["prefix_hits"] > hits1
    assert eng.spec.counters["rounds"] > 0


# -- MoR-capacitated drafts ------------------------------------------------

def _calibrated(cfg, api, seed=0, batches_n=2):
    from repro.core.deploy import calibrate_lm
    from repro.data.pipeline import synthetic_lm_batch
    params = api.init(jax.random.PRNGKey(seed), cfg)

    def batches():
        s = 0
        while True:
            b = synthetic_lm_batch(cfg, 4, 64, seed=seed, step=s)
            yield {"tokens": jnp.asarray(b["tokens"])}
            s += 1
    return calibrate_lm(params, cfg, api.forward, batches(), batches_n)


def test_spec_mor_draft_cap_deterministic_and_swept_without_recompile():
    """Drafting under clamped MoR plans: the engine completes every
    request with the exact token budget, reproduces itself bit-exactly,
    and sweeping ``draft_cap`` only swaps a traced leaf — the draft plan
    trees for different cap values share one treedef (one compiled step
    serves the whole sweep), while the draft tree's treedef differs
    from the target's (the two roles are distinct executables).

    No vanilla-identity assert here ON PURPOSE: tile capacity couples
    tokens within a dispatch (the live-tile cumsum spans the whole
    batch), so a K+1-wide verify under tiled plans is not bit-equal to
    1-wide decode — greedy identity is a dense-mode guarantee."""
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params, mor, _ = _calibrated(cfg, api)
    from repro.core.deploy import attach_plans
    from repro.core.executor import attach_draft_caps, map_plans
    target = attach_plans(mor, cfg, "tiled")
    tree = {c: jax.tree_util.tree_structure(
        map_plans(attach_draft_caps(target, c), lambda p: p.as_draft()))
        for c in (0.25, 0.75)}
    assert tree[0.25] == tree[0.75], "draft_cap sweep would recompile"
    assert tree[0.25] != jax.tree_util.tree_structure(target)

    reqs = _reqs(cfg, [(9, 8), (5, 6), (12, 10)], seed=2)
    kw = dict(mor=mor, mor_mode="tiled", n_slots=2, max_len=64,
              telemetry=False, spec_k=2, draft_cap=0.5)
    eng = Engine(cfg, params, **kw)
    res = eng.run(list(reqs))
    for rid, (_, g) in enumerate(reqs):
        assert len(res[rid]) == g
        assert all(0 <= t < cfg.vocab_size for t in res[rid])
    sp = eng.spec.report()
    assert sp["rounds"] > 0 and sp["tokens_drafted"] > 0
    assert sp["draft_cap"] == 0.5
    res2 = Engine(cfg, params, **kw).run(list(reqs))
    assert res2 == res, "MoR-draft speculation is nondeterministic"


# -- seeded sampling -------------------------------------------------------

def test_spec_sampled_seeded_reproducible():
    """Sampled speculation (the exact rejection rule end to end) is a
    pure function of the sample seed: two engines on the same trace
    emit identical tokens, every one in-vocab with the full budget."""
    cfg, params = _params("granite-3-2b")
    reqs = _reqs(cfg, [(8, 10), (5, 6)], seed=3)
    kw = dict(n_slots=2, max_len=64, telemetry=False, temperature=1.0,
              sample_seed=3, spec_k=3)
    a = Engine(cfg, params, **kw)
    res_a = a.run(list(reqs))
    res_b = Engine(cfg, params, **kw).run(list(reqs))
    assert res_a == res_b
    for rid, (_, g) in enumerate(reqs):
        assert len(res_a[rid]) == g
        assert all(0 <= t < cfg.vocab_size for t in res_a[rid])
    assert a.spec.counters["rounds"] > 0

"""Streaming regression calibration tests (paper §3.2.1)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core.calibration import (finalize_regression, init_accumulator,
                                    update_accumulator)

RNG = np.random.default_rng(2)


def _reference_fit(x, y):
    m = np.empty(x.shape[1])
    b = np.empty(x.shape[1])
    c = np.empty(x.shape[1])
    for j in range(x.shape[1]):
        m[j], b[j] = np.polyfit(x[:, j], y[:, j], 1)
        c[j] = np.corrcoef(x[:, j], y[:, j])[0, 1]
    return m, b, c


def test_streaming_matches_polyfit():
    T, N = 512, 9
    x = RNG.normal(size=(T, N)).astype(np.float64)
    y = 2.5 * x + 1.0 + 0.3 * RNG.normal(size=(T, N))
    acc = init_accumulator(N)
    # stream in 4 chunks — result must match a single-pass fit
    for i in range(0, T, 128):
        acc = update_accumulator(acc, jnp.asarray(x[i:i + 128]),
                                 jnp.asarray(y[i:i + 128]))
    m, b, c = finalize_regression(acc)
    m_ref, b_ref, c_ref = _reference_fit(x, y)
    np.testing.assert_allclose(np.asarray(m), m_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(b), b_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(c), c_ref, rtol=1e-3, atol=1e-3)


def test_degenerate_neuron_gets_zero_correlation():
    acc = init_accumulator(2)
    x = jnp.asarray([[1.0, 5.0]] * 32)          # constant x -> no variance
    y = jnp.asarray(RNG.normal(size=(32, 2)), jnp.float32)
    acc = update_accumulator(acc, x, y)
    _, _, c = finalize_regression(acc)
    np.testing.assert_allclose(np.asarray(c), [0.0, 0.0], atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 64), st.integers(1, 8),
       st.floats(-3, 3), st.floats(-2, 2))
def test_perfect_line_recovered(t, n, slope, intercept):
    """Property: exact linear data -> exact (m, b) and |c| = 1."""
    x = RNG.normal(size=(t + 2, n))
    y = slope * x + intercept
    acc = init_accumulator(n)
    acc = update_accumulator(acc, jnp.asarray(x), jnp.asarray(y))
    m, b, c = finalize_regression(acc)
    if abs(slope) > 1e-3:
        np.testing.assert_allclose(np.asarray(m), slope, rtol=2e-2,
                                   atol=2e-2)
        np.testing.assert_allclose(np.asarray(b), intercept, rtol=2e-2,
                                   atol=5e-2)
        assert np.all(np.abs(np.asarray(c)) > 0.99)

"""Unit tests for the hybrid predictor math (paper §3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictor import (binarize, binary_preact, estimate_preact,
                                  hybrid_predict, make_identity_layer,
                                  prediction_breakdown)
from repro.kernels.ref import binary_dot_ref

RNG = np.random.default_rng(0)


def test_binarize_signs_and_zero():
    from repro.core.predictor import binarize_act
    x = jnp.asarray([-2.0, -0.0, 0.0, 3.0])
    out = np.asarray(binarize(x))
    # weights: zero maps to +1 (sign-bit convention, paper §3.2.1)
    assert list(out) == [-1, 1, 1, 1]
    assert out.dtype == np.int8
    # activations: zero maps to -1 (post-ReLU zeros are informative)
    out_a = np.asarray(binarize_act(x))
    assert list(out_a) == [-1, -1, -1, 1]


def test_binary_preact_matches_oracle():
    x = jnp.asarray(RNG.normal(size=(7, 33)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(33, 11)), jnp.float32)
    np.testing.assert_array_equal(np.asarray(binary_preact(x, w)),
                                  np.asarray(binary_dot_ref(x, w)))


def test_binary_preact_is_bounded_by_k():
    x = jnp.asarray(RNG.normal(size=(5, 64)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(64, 9)), jnp.float32)
    p = np.asarray(binary_preact(x, w))
    assert np.all(np.abs(p) <= 64)
    # parity: +-1 sums over 64 terms are even
    assert np.all((p.astype(int) + 64) % 2 == 0)


def test_estimate_preact_bn_and_residual():
    mor = make_identity_layer(4)
    mor["m"] = jnp.asarray([2.0, 1.0, 1.0, 0.5])
    mor["b"] = jnp.asarray([0.0, 1.0, 0.0, 0.0])
    mor["bn_scale"] = jnp.asarray([1.0, 1.0, 3.0, 1.0])
    mor["bn_bias"] = jnp.asarray([0.0, 0.0, -1.0, 2.0])
    p_bin = jnp.ones((2, 4))
    res = jnp.full((2, 4), 10.0)
    # paper §3.2.1: p_hat = (m*p_bin + b)*scale + bias (+ residual)
    got = np.asarray(estimate_preact(p_bin, mor, residual=res))
    want = np.asarray([(2 * 1 + 0) * 1 + 0 + 10, (1 + 1) * 1 + 0 + 10,
                       (1 + 0) * 3 - 1 + 10, (0.5 + 0) * 1 + 2 + 10])
    np.testing.assert_allclose(got[0], want)


def test_hybrid_skips_only_when_both_agree():
    """A neuron is skipped iff BOTH rookies predict zero (paper §3.2)."""
    K, N, T = 32, 8, 16
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(T, K)), jnp.float32)
    mor = make_identity_layer(N)
    # make neuron 3 a member of proxy 0's cluster, enabled, and force the
    # binary rookie to predict very negative via m<0... instead use real
    # pre-acts: enable all, proxies: neuron 0 proxies everyone
    mor["enable"] = jnp.ones((N,), bool)
    mor["is_proxy"] = jnp.asarray([True] + [False] * (N - 1))
    mor["proxy_slot"] = jnp.zeros((N,), jnp.int32)
    pre = x @ w
    computed = np.asarray(hybrid_predict(x, w, mor, preact_full=pre))
    # proxies are never skipped
    assert computed[:, 0].all()
    p_bin = np.asarray(binary_preact(x, w))
    proxy_neg = np.asarray(pre)[:, 0] < 0
    for t in range(T):
        for j in range(1, N):
            expect_skip = proxy_neg[t] and (p_bin[t, j] < 0)
            assert computed[t, j] == (not expect_skip)


def test_prediction_breakdown_sums_to_one():
    pre = jnp.asarray(RNG.normal(size=(64, 32)), jnp.float32)
    mask = jnp.asarray(RNG.random((64, 32)) > 0.3)
    bd = prediction_breakdown(pre, mask)
    total = sum(float(v) for v in bd.values())
    assert abs(total - 1.0) < 1e-6
    # mispredicted zeros are exactly: predicted zero but truly positive
    want = float(jnp.mean(~mask & (pre > 0)))
    assert abs(float(bd["incorrect_zero"]) - want) < 1e-6

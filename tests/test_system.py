"""End-to-end behaviour tests for the full system (the paper's pipeline:
train -> calibrate -> MoR-guarded inference), plus the HLO cost analyzer
the roofline is built on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config


def test_training_reduces_loss():
    from repro.launch.train import main as train_main
    r = train_main(["--arch", "granite-3-2b", "--reduced", "--steps", "40",
                    "--batch", "8", "--seq", "48", "--log-every", "100"])
    assert r["loss_last"] < r["loss_first"] - 0.1


def test_serve_mor_exact_token_agreement():
    """The paper's accuracy claim, system-level: MoR-guarded decoding
    produces (near-)identical tokens to dense decoding."""
    from repro.launch.serve import main as serve_main
    r = serve_main(["--arch", "granite-3-2b", "--reduced", "--batch", "4",
                    "--prompt-len", "8", "--gen-len", "12",
                    "--mor", "exact", "--compare"])
    assert r["token_agreement_vs_dense"] >= 0.9


def test_calibrate_lm_permutation_preserves_dense_math():
    """Folding the cluster permutation into the FFN weights must leave the
    dense forward numerically unchanged (perm cancels through w_down)."""
    from repro.core.deploy import calibrate_lm
    from repro.data.pipeline import synthetic_lm_batch
    from repro.models import get_model
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    def batches():
        s = 0
        while True:
            b = synthetic_lm_batch(cfg, 4, 64, seed=0, step=s)
            yield {"tokens": jnp.asarray(b["tokens"])}
            s += 1
    params2, mor, rep = calibrate_lm(params, cfg, api.forward, batches(), 2)
    toks = jnp.asarray(synthetic_lm_batch(cfg, 2, 16, seed=1,
                                          step=0)["tokens"])
    l1, _ = api.forward(params, cfg, {"tokens": toks})
    l2, _ = api.forward(params2, cfg, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-3)
    assert 0.0 <= rep["pearson_mean"] <= 1.0


def test_rwkv_native_relu2_mor_pipeline():
    """MoR applies natively (no relufication) to RWKV channel-mix."""
    from repro.core.deploy import calibrate_lm
    from repro.data.pipeline import synthetic_lm_batch
    from repro.models import get_model
    cfg = reduce_config(get_config("rwkv6-3b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    def batches():
        s = 0
        while True:
            b = synthetic_lm_batch(cfg, 2, 32, seed=0, step=s)
            yield {"tokens": jnp.asarray(b["tokens"])}
            s += 1
    params2, mor, rep = calibrate_lm(params, cfg, api.forward, batches(), 2)
    toks = jnp.asarray(synthetic_lm_batch(cfg, 2, 8, seed=1,
                                          step=0)["tokens"])
    lg, aux = api.forward(params2, cfg, {"tokens": toks}, mor=mor,
                          mor_mode="exact")
    assert bool(jnp.all(jnp.isfinite(lg)))
    assert "mor_stats" in aux


def test_hlo_cost_scan_trip_counts():
    """The roofline's foundation: loop bodies are multiplied by their trip
    counts (XLA's own cost_analysis counts them once)."""
    from repro.launch import hlo_cost

    def g(a, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, a, ws)
        return y

    comp = jax.jit(g).lower(
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)).compile()
    res = hlo_cost.analyze(comp.as_text())
    want = 10 * 2 * 128 * 256 * 256
    assert abs(res["flops"] - want) / want < 1e-6
    xla = comp.cost_analysis()
    xla_flops = float((xla[0] if isinstance(xla, (list, tuple))
                       else xla).get("flops", 0))
    assert xla_flops < res["flops"]  # documents why hlo_cost exists


def test_hlo_cost_weight_streaming_bytes():
    """dynamic-slice from a loop-invariant stack is charged at slice size
    (one layer per trip), not the full stack."""
    from repro.launch import hlo_cost

    def g(a, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, a, ws)
        return y

    L, D = 20, 128
    comp = jax.jit(g).lower(
        jax.ShapeDtypeStruct((8, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
    res = hlo_cost.analyze(comp.as_text())
    w_bytes = L * D * D * 4
    # total traffic must be O(one pass over the weights), not O(L * stack)
    assert res["bytes"] < 6 * w_bytes
    assert res["bytes"] > w_bytes  # and at least one pass


def test_dryrun_cell_status_grid():
    """The 40-cell grid resolves to the DESIGN.md §Arch-applicability
    skip/run statuses."""
    from repro.launch.dryrun import cell_status
    from repro.configs import SHAPES
    assert cell_status(get_config("qwen2-7b"), SHAPES["train_4k"]) == "run"
    assert cell_status(get_config("rwkv6-3b"), SHAPES["long_500k"]) == "run"
    assert cell_status(get_config("zamba2-7b"), SHAPES["long_500k"]) == "run"
    assert cell_status(get_config("mixtral-8x7b"),
                       SHAPES["long_500k"]) == "run"
    assert "skip" in cell_status(get_config("qwen2-7b"),
                                 SHAPES["long_500k"])
    assert "skip" in cell_status(get_config("hubert-xlarge"),
                                 SHAPES["decode_32k"])
    n_run = 0
    from repro.launch.dryrun_all import ARCHS, SHAPE_NAMES
    for a in ARCHS:
        for s in SHAPE_NAMES:
            n_run += cell_status(get_config(a), SHAPES[s]) == "run"
    assert n_run == 32  # 40 cells - 8 mandated skips

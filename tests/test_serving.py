"""repro.serving engine tests: chunked prefill == batched prefill ==
teacher-forced forward (transformer / ssm / hybrid / rwkv, incl. prompts
beyond the sliding-window ring), the paged-vs-slotted cache-layout
equivalence matrix + shared-prefix dedup, the mesh-sharded paged layout
(paged-sharded == paged on 4 forced host devices, one merge collective
per attention layer), continuous-batching slot eviction/reuse vs solo
runs, the detokenizing stream API, temperature/top-k sampling,
telemetry-driven capacity calibration, and the rebuilt serve driver's
report."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import get_model
from repro.serving import Engine, kv_pool
from repro.serving.telemetry import ServingTelemetry, calibrate_capacity


def _chunked_prefill(cfg, api, params, toks, chunk, n_slots=None,
                     max_len=64):
    """Drive api.prefill_chunk over toks (B, P) in ``chunk``-size pieces;
    returns (all-position logits (B, P, V), cache)."""
    B, P = toks.shape
    cache = kv_pool.init(cfg, n_slots or B, max_len, chunk)
    outs = []
    off = 0
    while off < P:
        take = min(chunk, P - off)
        piece = jnp.pad(toks[:, off:off + take],
                        ((0, 0), (0, chunk - take)))
        lg, cache, _ = api.prefill_chunk(
            params, cfg, piece, cache,
            n_valid=jnp.full((B,), take, jnp.int32))
        outs.append(np.asarray(lg)[:, :take])
        off += take
    return np.concatenate(outs, 1), cache


# -- chunked prefill == teacher-forced forward, all decoder families -------

def _reduced(arch):
    cfg = reduce_config(get_config(arch))
    if arch == "deepseek-v2-236b":
        # isolate the MLA attention math from MoE expert-capacity
        # effects (capacity depends on the dispatch token count, so MoE
        # logits legitimately depend on batch shape)
        cfg = cfg.replace(family="dense", n_experts=0, top_k=0,
                          first_k_dense=0, n_shared_experts=0)
    return cfg


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen2-7b",
                                  "deepseek-v2-236b", "rwkv6-3b",
                                  "zamba2-7b"])
def test_chunked_prefill_matches_forward(arch):
    """Chunk boundaries (incl. a partial final chunk) must be invisible:
    chaining prefill_chunk reproduces the teacher-forced forward logits
    at EVERY position for attention (gqa + absorbed-latent mla), ssm
    (rwkv) and hybrid (mamba + shared-attn) families."""
    cfg = _reduced(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    P = 13                                # not a multiple of the chunk
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, P), 0,
                              cfg.vocab_size)
    want, _ = api.forward(params, cfg, {"tokens": toks})
    got, _ = _chunked_prefill(cfg, api, params, toks, chunk=5)
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_chunked_prefill_matches_batched_prefill():
    """Where a one-shot batched prefill exists (transformer), chunked
    prefill must agree with it, and a decode step continues identically
    from either cache."""
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    B, P = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, P + 1), 0,
                              cfg.vocab_size)
    got, cache_c = _chunked_prefill(cfg, api, params, toks[:, :P], chunk=5)
    cache_b = kv_pool.init(cfg, B, 64)
    lg_b, cache_b = api.prefill(params, cfg, toks[:, :P], cache_b)
    np.testing.assert_allclose(got[:, -1], np.asarray(lg_b, np.float32),
                               rtol=2e-4, atol=2e-4)
    # decode continues consistently from the chunk-built cache
    lg_c, _, _ = api.prefill_chunk(params, cfg, toks[:, P:P + 1], cache_c,
                                   n_valid=jnp.ones((B,), jnp.int32))
    full, _ = api.forward(params, cfg, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg_c)[:, 0],
                               np.asarray(full, np.float32)[:, -1],
                               rtol=2e-3, atol=2e-3)


def test_chunked_prefill_beyond_sliding_window_ring():
    """The acceptance criterion that killed the scanned-decode fallback:
    a prompt far longer than the sliding-window ring buffer prefills in
    chunks with logits identical to the teacher-forced forward (the
    kv_pool ring carries a chunk-size margin above the window)."""
    cfg = reduce_config(get_config("granite-3-2b")).replace(
        sliding_window=16)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    P, C = 40, 8                          # P >> window
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, P), 0,
                              cfg.vocab_size)
    want, _ = api.forward(params, cfg, {"tokens": toks})
    got, _ = _chunked_prefill(cfg, api, params, toks, chunk=C)
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def _lossless_ref(cfg):
    """A capacity factor at which the teacher-forced forward provably
    drops nothing (C = cf*T*k/E >= T: an expert can receive at most one
    slot per token) — the drop-free reference the serving-shape-aware
    chunk path must now reproduce exactly."""
    return cfg.replace(capacity_factor=float(cfg.n_experts) / cfg.top_k)


def test_moe_chunk_slot_isolation():
    """Padded/invalid rows must not claim MoE expert capacity, and the
    serving-shape-aware capacity (C provisioned from the dispatch shape,
    lossless by construction) must reproduce the drop-free teacher-forced
    forward from a single full-prompt chunk."""
    cfg = reduce_config(get_config("mixtral-8x7b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    B, P = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                              cfg.vocab_size)
    want, _ = api.forward(params, _lossless_ref(cfg), {"tokens": toks})
    cache = kv_pool.init(cfg, B, 32, P)
    got, _, _ = api.prefill_chunk(params, cfg, toks, cache,
                                  n_valid=jnp.full((B,), P, jnp.int32))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_moe_chunked_prefill_matches_teacher_forced():
    """The ROADMAP serving follow-up, closed: MoE chunked prefill used to
    diverge from teacher-forced logits BY DESIGN (expert capacity scaled
    with each dispatch's token count, so small/mixed dispatches dropped
    tokens the full forward kept).  With the serving-shape-aware capacity
    factor every chunk dispatch is drop-free, so chaining chunks of ANY
    size reproduces the drop-free forward at every position — including
    a ragged final chunk and a decode-shaped (B, 1) continuation."""
    cfg = reduce_config(get_config("mixtral-8x7b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    B, P = 2, 13
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, P + 1), 0,
                              cfg.vocab_size)
    want, _ = api.forward(params, _lossless_ref(cfg),
                          {"tokens": toks[:, :P]})
    got, cache = _chunked_prefill(cfg, api, params, toks[:, :P], chunk=5)
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)
    # decode-shaped dispatch continues exactly (T = B tokens: precisely
    # the shape where the old cf*T*k/E budget starved experts)
    lg, _, _ = api.prefill_chunk(params, cfg, toks[:, P:P + 1], cache,
                                 n_valid=jnp.ones((B,), jnp.int32))
    full, _ = api.forward(params, _lossless_ref(cfg), {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg)[:, 0],
                               np.asarray(full, np.float32)[:, -1],
                               rtol=2e-3, atol=2e-3)


def test_moe_engine_slot_eviction_reuse_matches_solo():
    """MoE requests through a shared slot pool: because serving capacity
    is now dispatch-shape-aware (drop-free), a request's greedy tokens
    cannot depend on which other slots it was co-scheduled with — every
    request must match a solo run despite eviction/slot reuse mid-flight."""
    cfg = reduce_config(get_config("mixtral-8x7b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    reqs = [(rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 14))),
             int(rng.integers(3, 6))) for _ in range(4)]
    eng = Engine(cfg, params, n_slots=2, max_len=64)
    res = eng.run(list(reqs))
    assert len(res) == len(reqs)
    for i, (p, g) in enumerate(reqs):
        solo = Engine(cfg, params, n_slots=1, max_len=64)
        want = solo.run([(p, g)])[0]
        assert res[i] == want, f"moe request {i} diverged under sharing"


def test_make_prefill_step_has_no_scanned_fallback():
    """steps.make_prefill_step routes recurrent families through chunked
    prefill (api.prefill_chunk), never a scanned decode_step."""
    from repro.launch import steps
    cfg = reduce_config(get_config("rwkv6-3b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    import repro.models.rwkv_model as rm
    calls = {"decode": 0}
    orig = rm.decode_step

    def spy(*a, **k):
        calls["decode"] += 1
        return orig(*a, **k)
    rm.decode_step = spy
    try:
        prefill = steps.make_prefill_step(cfg)
        cache = kv_pool.init(cfg, 2, 64)
        toks = jax.random.randint(jax.random.PRNGKey(4), (2, 11), 0,
                                  cfg.vocab_size)
        nxt, cache = prefill(params, cache, toks)
    finally:
        rm.decode_step = orig
    assert calls["decode"] == 0, "scanned-decode fallback still in use"
    # and it agrees with the teacher-forced forward's next token
    full, _ = api.forward(params, cfg, {"tokens": toks})
    want = np.argmax(np.asarray(full, np.float32)[:, -1], -1)
    np.testing.assert_array_equal(np.asarray(nxt), want)


# -- paged pool: logits equivalence + prefix caching -----------------------

def _paged_chunked_prefill(cfg, api, params, toks, chunk, max_len=64):
    """Drive api.prefill_chunk over the PAGED layout the way the engine
    does (allocate/COW before each dispatch via PagedPool.prepare);
    returns all-position logits (B, P, V)."""
    B, P = toks.shape
    pool = kv_pool.PagedPool(cfg, B, max_len, chunk=chunk)
    cache = pool.build()
    outs, off = [], 0
    while off < P:
        take = min(chunk, P - off)
        piece = jnp.pad(toks[:, off:off + take],
                        ((0, 0), (0, chunk - take)))
        nv = np.full((B,), take, np.int64)
        cache = pool.prepare(cache, nv)
        lg, cache, _ = api.prefill_chunk(
            params, cfg, piece, cache,
            n_valid=jnp.asarray(nv, jnp.int32))
        pool.advance(nv)
        outs.append(np.asarray(lg)[:, :take])
        off += take
    return np.concatenate(outs, 1)


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen2-7b",
                                  "deepseek-v2-236b", "rwkv6-3b",
                                  "zamba2-7b"])
def test_paged_chunked_prefill_matches_forward(arch):
    """The paging acceptance criterion: block-table indirection must be
    invisible — paged chunked prefill reproduces the teacher-forced
    forward logits at EVERY position for attention (gqa ring + absorbed
    MLA), ssm (state-table indirection) and hybrid (both) families."""
    cfg = _reduced(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    P = 13
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, P), 0,
                              cfg.vocab_size)
    want, _ = api.forward(params, cfg, {"tokens": toks})
    got = _paged_chunked_prefill(cfg, api, params, toks, chunk=5)
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_paged_chunked_prefill_beyond_sliding_window_ring():
    """Ring wrap through the block tables: a prompt far beyond the
    sliding-window ring still matches the teacher-forced forward."""
    cfg = reduce_config(get_config("granite-3-2b")).replace(
        sliding_window=16)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 40), 0,
                              cfg.vocab_size)
    want, _ = api.forward(params, cfg, {"tokens": toks})
    got = _paged_chunked_prefill(cfg, api, params, toks, chunk=8)
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["granite-3-2b", "deepseek-v2-236b",
                                  "rwkv6-3b", "zamba2-7b", "mixtral-8x7b"])
def test_paged_engine_matches_slotted(arch):
    """The paged-vs-slotted equivalence matrix: the same heterogeneous
    trace through both cache layouts (incl. mid-flight eviction and slot
    reuse) produces identical greedy tokens for every family."""
    cfg = reduce_config(get_config(arch))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 18))),
             int(rng.integers(3, 7))) for _ in range(5)]
    res_p = Engine(cfg, params, n_slots=2, max_len=64,
                   layout="paged").run(list(reqs))
    res_s = Engine(cfg, params, n_slots=2, max_len=64,
                   layout="slotted").run(list(reqs))
    assert res_p == res_s, f"{arch}: paged tokens diverge from slotted"


def test_paged_engine_matches_slotted_sliding_window():
    """Same matrix under a sliding window small enough that decode wraps
    the ring (COW against published prefix pages on the wrap path)."""
    cfg = reduce_config(get_config("granite-3-2b")).replace(
        sliding_window=16)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    reqs = [(rng.integers(0, cfg.vocab_size, size=int(rng.integers(20, 40))),
             int(rng.integers(8, 16))) for _ in range(3)]
    res_p = Engine(cfg, params, n_slots=2, max_len=96,
                   layout="paged").run(list(reqs))
    res_s = Engine(cfg, params, n_slots=2, max_len=96,
                   layout="slotted").run(list(reqs))
    assert res_p == res_s


@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-3b", "zamba2-7b"])
def test_shared_prefix_dedup(arch):
    """The prefix-caching acceptance criterion: a shared-prompt trace
    produces IDENTICAL tokens with and without the cache, while the
    warm engine dispatches measurably less prefill (>0 chunks skipped,
    hit rate reported) — via shared KV pages for attention and state
    snapshots (+ shared-attention pages) for ssm/hybrid."""
    cfg = reduce_config(get_config(arch))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prefix = rng.integers(0, cfg.vocab_size, size=24)
    reqs = [(np.concatenate([prefix,
                             rng.integers(0, cfg.vocab_size, size=4)]), 5)
            for _ in range(4)]
    warm = Engine(cfg, params, n_slots=2, max_len=64, chunk=8)
    cold = Engine(cfg, params, n_slots=2, max_len=64, chunk=8,
                  prefix_cache=False)
    res_w = warm.run(list(reqs))
    res_c = cold.run(list(reqs))
    assert res_w == res_c, f"{arch}: prefix cache changed tokens"
    pc = warm._prefix_counters()
    assert pc["prefix_hits"] > 0 and pc["hit_rate"] > 0
    assert pc["chunks_skipped"] > 0, "no prefill chunk was skipped"
    assert warm.counters["prefill_tokens"] < cold.counters["prefill_tokens"]
    assert warm.counters["dispatches"] < cold.counters["dispatches"]
    rep = warm.report()
    assert rep["prefix_cache"]["chunks_skipped"] == pc["chunks_skipped"]
    assert rep["telemetry"]["prefix_cache"]["hit_rate"] == pc["hit_rate"]


def test_prefix_cache_survives_eviction_and_rehits():
    """Pages published by a finished (evicted) request stay pinned by
    the trie and serve hits for requests admitted much later."""
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prefix = rng.integers(0, cfg.vocab_size, size=16)
    eng = Engine(cfg, params, n_slots=1, max_len=64, chunk=8)
    first = eng.run([(np.concatenate([prefix, [7]]), 4)])
    hits_before = eng._prefix_counters()["prefix_hits"]
    # same prompt again, after the first request was fully evicted
    second = eng.run([(np.concatenate([prefix, [7]]), 4)])
    assert list(first.values())[0] == list(second.values())[0]
    assert eng._prefix_counters()["prefix_hits"] == hits_before + 1


# -- mesh-sharded paged layout (ISSUE 5) -----------------------------------

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduce_config
from repro.models import get_model
from repro.serving import Engine
from repro.launch.mesh import make_page_mesh

mesh = make_page_mesh(4)
# the 5-family matrix: gqa ring, absorbed MLA, recurrent state tables,
# hybrid (state + shared-attn pages), MoE — paged-sharded must be token-
# identical to the single-device paged engine on the same heterogeneous
# trace (which the existing matrix ties to slotted and teacher-forced)
for arch in ["granite-3-2b", "deepseek-v2-236b", "rwkv6-3b",
             "zamba2-7b", "mixtral-8x7b"]:
    cfg = reduce_config(get_config(arch))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 18))),
             int(rng.integers(3, 7))) for _ in range(3)]
    res_p = Engine(cfg, params, n_slots=2, max_len=64,
                   layout="paged").run(list(reqs))
    eng = Engine(cfg, params, n_slots=2, max_len=64,
                 layout="paged-sharded", mesh=mesh)
    res_m = eng.run(list(reqs))
    assert res_m == res_p, arch + ": sharded tokens diverge from paged"
    sh = eng.pool.shard_report()
    hw = (sh.get("kv_pages_hiwater_per_shard")
          or sh.get("state_pages_hiwater_per_shard"))
    assert sum(1 for n in hw if n > 0) >= 2, (arch, sh)
    assert eng.report()["sharding"]["n_shards"] == 4
    print("MATRIX_OK", arch)

# prefix cache OFF must also agree (acceptance: on AND off)
cfg = reduce_config(get_config("granite-3-2b"))
api = get_model(cfg)
params = api.init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(1)
reqs = [(rng.integers(0, cfg.vocab_size, size=12), 4) for _ in range(3)]
res_p = Engine(cfg, params, n_slots=2, max_len=64, layout="paged",
               prefix_cache=False).run(list(reqs))
eng = Engine(cfg, params, n_slots=2, max_len=64, layout="paged-sharded",
             mesh=mesh, prefix_cache=False)
assert eng.run(list(reqs)) == res_p, "prefix-off sharded tokens diverge"
print("PREFIX_OFF_OK")

# shared-prefix dedup works unchanged on the sharded pool
prefix = rng.integers(0, cfg.vocab_size, size=24)
sreqs = [(np.concatenate([prefix,
                          rng.integers(0, cfg.vocab_size, size=4)]), 4)
         for _ in range(3)]
warm = Engine(cfg, params, n_slots=2, max_len=64, chunk=8,
              layout="paged-sharded", mesh=mesh)
cold = Engine(cfg, params, n_slots=2, max_len=64, chunk=8,
              layout="paged-sharded", mesh=mesh, prefix_cache=False)
assert warm.run(list(sreqs)) == cold.run(list(sreqs))
assert warm._prefix_counters()["chunks_skipped"] > 0
print("SHARDED_PREFIX_OK")

# the distributed flash-decode merge is ONE collective per attention
# layer per dispatch: the paged layer loop is unrolled (per-layer tuple
# pool leaves keep the scatters in-place), so the lowered decode step
# carries exactly n_layers all-gathers (the packed flash merges) and
# nothing else
lowered = eng._step.lower(
    params, None, eng.cache, jnp.zeros((2, 1), jnp.int32),
    jnp.ones((2,), jnp.int32), jnp.ones((2,), bool), eng._pending,
    eng._base_key, None)
lines = lowered.as_text().splitlines()
n_ag = sum(1 for ln in lines if "all_gather" in ln or "all-gather" in ln)
n_other = sum(1 for ln in lines
              if "all_reduce" in ln or "all-reduce" in ln
              or "collective_permute" in ln or "collective-permute" in ln)
assert n_ag == cfg.n_layers, \
    f"expected one merge collective per layer ({cfg.n_layers}), got {n_ag}"
assert n_other == 0, f"unexpected extra collectives: {n_other}"
print("COLLECTIVE_COUNT_OK")
print("SHARDED_OK")
"""


def test_paged_sharded_engine_matrix_multidevice():
    """The ISSUE 5 acceptance matrix, run in a subprocess with 4 forced
    host devices (jax device count locks at first init): paged-sharded
    == paged tokens for all 5 families, with prefix cache on and off,
    pages spread over the shards, and exactly ONE merge collective per
    attention layer in the compiled decode step."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.getcwd(), timeout=1800)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED_OK" in r.stdout


def test_paged_sharded_single_device_mesh():
    """The degenerate 1-shard mesh runs in-process (no forced devices)
    and must match the plain paged engine — the layout flag alone can't
    change tokens."""
    from repro.launch.mesh import make_page_mesh
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    reqs = [(rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 14))),
             int(rng.integers(3, 6))) for _ in range(3)]
    res_p = Engine(cfg, params, n_slots=2, max_len=64,
                   layout="paged").run(list(reqs))
    eng = Engine(cfg, params, n_slots=2, max_len=64,
                 layout="paged-sharded", mesh=make_page_mesh(1))
    assert eng.run(list(reqs)) == res_p


# -- detokenizing stream API ------------------------------------------------

def test_stream_callback_matches_results():
    """submit(on_token=...) fires per generated token in order at flush
    time; the callback stream equals the request's result list, and
    requests without callbacks are untouched (default off)."""
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)
    eng = Engine(cfg, params, n_slots=2, max_len=64)
    got = []
    rid = eng.submit(rng.integers(0, cfg.vocab_size, size=9), 6,
                     on_token=lambda r, t: got.append((r, t)))
    rid2 = eng.submit(rng.integers(0, cfg.vocab_size, size=5), 4)
    eng.run()
    assert [t for _, t in got] == eng.results[rid]
    assert all(r == rid for r, _ in got)
    assert len(eng.results[rid2]) == 4


def test_stream_iterator_yields_incrementally():
    """Engine.stream() yields tokens while the engine is still serving
    (flush every `interval` dispatches), and the full stream equals a
    plain run of the same request."""
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, size=11)
    want = Engine(cfg, params, n_slots=1, max_len=64).run([(prompt, 6)])
    eng = Engine(cfg, params, n_slots=1, max_len=64)
    toks, midway = [], False
    for t in eng.stream(prompt, 6, interval=1):
        toks.append(t)
        if eng.scheduler.has_work:
            midway = True
    assert toks == list(want.values())[0]
    assert midway, "stream only delivered after completion"


def test_stream_submits_eagerly_and_releases_callbacks():
    """stream() must queue the request at CALL time (a later run()
    serves it and the generator replays the flushed tokens), and a
    long-lived engine must not accumulate finished streams' callbacks."""
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(10)
    eng = Engine(cfg, params, n_slots=2, max_len=64)
    for _ in range(2):
        assert len(list(eng.stream(
            rng.integers(0, cfg.vocab_size, size=6), 4))) == 4
    assert not eng._stream_cbs, "finished stream callbacks leaked"
    it = eng.stream(rng.integers(0, cfg.vocab_size, size=6), 4)
    eng.run()                            # serves the streamed request
    assert list(it) == eng.results[max(eng.results)]
    assert not eng._stream_cbs


def test_run_stream_interval_preserves_tokens():
    """Opt-in periodic flushing must not change results (the flush only
    moves when tokens reach the host, never what they are)."""
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(8)
    reqs = [(rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 14))),
             int(rng.integers(3, 6))) for _ in range(3)]
    a = Engine(cfg, params, n_slots=2, max_len=64).run(list(reqs))
    b = Engine(cfg, params, n_slots=2, max_len=64).run(
        list(reqs), stream_interval=1)
    assert a == b


# -- windowed prompts longer than the ring: pre-wrap publish ---------------

def test_windowed_prompt_publishes_prewrap_prefix():
    """The ROADMAP gap, closed: a sliding-window prompt LONGER than its
    ring used to publish nothing (by prefill's end the ring has wrapped
    over the prefix pages).  Now the engine publishes at the last
    pre-wrap page boundary, so an identical later prompt hits, skips
    whole chunks, and still produces identical tokens."""
    cfg = reduce_config(get_config("granite-3-2b")).replace(
        sliding_window=16)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, size=40)   # ring = 16+8 -> 24
    reqs = [(prompt, 5), (prompt, 5)]
    warm = Engine(cfg, params, n_slots=1, max_len=96, chunk=8)
    cold = Engine(cfg, params, n_slots=1, max_len=96, chunk=8,
                  prefix_cache=False)
    res_w = warm.run(list(reqs))
    res_c = cold.run(list(reqs))
    assert list(res_w.values()) == list(res_c.values()), \
        "pre-wrap publish changed tokens"
    pc = warm._prefix_counters()
    assert pc["prefix_hits"] > 0, "windowed prompt still publishes nothing"
    assert pc["chunks_skipped"] > 0
    # the hit covers exactly the pre-wrap boundary (ring rows), so the
    # reused prefix never includes wrapped (overwritten) pages
    assert pc["tokens_skipped"] == warm.pool.ring


# -- sampling ---------------------------------------------------------------

def test_sampling_topk1_equals_greedy_and_seed_reproducible():
    """temperature>0 with top_k=1 must reduce to greedy argmax, and the
    same sampling seed must reproduce the same stream (the sampler is
    seeded + device-resident like the rest of the hot loop)."""
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, cfg.vocab_size, size=7), 6)]
    greedy = Engine(cfg, params, n_slots=1, max_len=64).run(list(reqs))
    top1 = Engine(cfg, params, n_slots=1, max_len=64, temperature=0.7,
                  top_k=1).run(list(reqs))
    assert greedy == top1
    sa = Engine(cfg, params, n_slots=1, max_len=64, temperature=1.0,
                sample_seed=3).run(list(reqs))
    sb = Engine(cfg, params, n_slots=1, max_len=64, temperature=1.0,
                sample_seed=3).run(list(reqs))
    assert sa == sb
    rep = Engine(cfg, params, n_slots=1, max_len=64, temperature=1.0,
                 top_k=5)
    rep.run(list(reqs))
    assert rep.report()["sampling"] == {"temperature": 1.0, "top_k": 5}


# -- continuous batching: eviction / slot reuse ----------------------------

@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-3b"])
def test_engine_slot_eviction_reuse_matches_solo(arch):
    """5 requests with heterogeneous prompt/gen lengths through 2 slots:
    finished sequences are evicted mid-flight and their slots recycled;
    every request's greedy tokens must equal running it alone."""
    cfg = reduce_config(get_config(arch))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 18))),
             int(rng.integers(3, 7))) for _ in range(5)]
    eng = Engine(cfg, params, n_slots=2, max_len=64)
    res = eng.run(list(reqs))
    assert len(res) == len(reqs)
    assert eng.counters["dispatches"] > 0
    for i, (p, g) in enumerate(reqs):
        solo = Engine(cfg, params, n_slots=1, max_len=64)
        want = solo.run([(p, g)])[0]
        assert res[i] == want, f"request {i} diverged under slot sharing"


def test_engine_mixed_dispatch_interleaves_prefill_and_decode():
    """While one slot prefills a long prompt in chunks, a decoding slot
    keeps generating inside the same dispatches (no decode stall)."""
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    eng = Engine(cfg, params, n_slots=2, max_len=128, chunk=8)
    eng.submit(rng.integers(0, cfg.vocab_size, size=4), 20)
    # short prompt finishes prefill first and starts decoding
    for _ in range(3):
        eng.step()
    decoded_before = eng.scheduler.slots[0].n_generated
    # long prompt admitted into slot 1: decode must continue during its
    # chunked prefill (mixed dispatches)
    eng.submit(rng.integers(0, cfg.vocab_size, size=64), 4)
    for _ in range(4):
        eng.step()
    decoded_after = eng.scheduler.slots[0].n_generated
    assert decoded_after > decoded_before
    eng.run()
    assert len(eng.results) == 2


# -- telemetry + capacity calibration --------------------------------------

def _calibrated(cfg, api, seed=0, batches_n=2):
    from repro.core.deploy import calibrate_lm
    from repro.data.pipeline import synthetic_lm_batch
    params = api.init(jax.random.PRNGKey(seed), cfg)

    def batches():
        s = 0
        while True:
            b = synthetic_lm_batch(cfg, 4, 64, seed=seed, step=s)
            yield {"tokens": jnp.asarray(b["tokens"])}
            s += 1
    return calibrate_lm(params, cfg, api.forward, batches(), batches_n)


@pytest.mark.parametrize("mode", ["tiled", "kernel"])
def test_engine_telemetry_and_capacity_calibration(mode):
    """Serving accumulates per-layer tile-liveness histograms; the
    calibrated per-layer capacities attach to the execution plans and
    the engine keeps producing finite outputs with them."""
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params, mor, _ = _calibrated(cfg, api)
    rng = np.random.default_rng(2)
    reqs = [(rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 16))),
             4) for _ in range(3)]
    eng = Engine(cfg, params, mor=mor, mor_mode=mode, n_slots=2, max_len=64)
    res = eng.run(list(reqs))
    assert len(res) == len(reqs)
    tel = eng.telemetry
    assert tel.n_updates > 0
    assert "mor_stats" in tel.hist
    assert tel.hist["mor_stats"].shape[0] == cfg.n_layers
    caps = eng.calibrate_capacities(quantile=0.9)
    arr = caps["mor_stats"]
    assert arr.shape == (cfg.n_layers,)
    assert np.all((arr > 0.0) & (arr <= 1.0))
    # plans now carry the per-layer budget as a traced leaf
    assert eng.mor["layers"].cap_live is not None
    res2 = eng.run(list(reqs))          # returns THIS call's requests
    assert len(res2) == len(reqs)
    assert len(eng.results) == 2 * len(reqs)   # all-time accumulation
    rep = eng.report()
    assert "per_layer_capacity" in rep


def test_calibrate_capacity_quantile_math():
    """The quantile provisioning reads the histogram, not the mean."""
    tel = ServingTelemetry(n_bins=10)
    # layer 0 mostly 20% live with rare 90% spikes; layer 1 always 50%
    for _ in range(18):
        tel.update({"mor_stats": {
            "frac_tiles_live": np.array([0.15, 0.45])}})
    for _ in range(2):
        tel.update({"mor_stats": {
            "frac_tiles_live": np.array([0.85, 0.45])}})
    caps = calibrate_capacity(tel, quantile=0.85, floor=0.05)["mor_stats"]
    assert caps[0] == pytest.approx(0.2, abs=0.05)   # spike clipped away
    assert caps[1] == pytest.approx(0.5, abs=0.05)
    caps_hi = calibrate_capacity(tel, quantile=0.99)["mor_stats"]
    assert caps_hi[0] >= 0.85                        # spike provisioned


def test_plan_cap_live_clamps_tiles():
    """A plan's traced cap_live budget clamps kept tiles below demand
    without recompilation (same treedef, new leaf values)."""
    from repro.core.executor import MoRExecutionPlan
    from repro.core.predictor import make_identity_layer
    N = 256
    layer = make_identity_layer(N)
    # force the predictor on: everything enabled, no proxies
    layer["enable"] = jnp.ones((N,), bool)
    layer["is_proxy"] = jnp.zeros((N,), bool)
    layer["proxy_slot"] = jnp.full((N,), -1, jnp.int32)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(16, 64)),
                    jnp.float32)
    w = jnp.asarray(np.random.default_rng(4).normal(size=(64, N)),
                    jnp.float32)
    full = MoRExecutionPlan(layer, mode="tiled", tile_m=8, tile_n=64)
    clamped = MoRExecutionPlan(layer, mode="tiled", tile_m=8, tile_n=64,
                               cap_live=jnp.asarray(0.5, jnp.float32))
    pf = full.predict(x, w)
    pc = clamped.predict(x, w)
    n_tiles = pf.tiles.size
    assert int(jnp.sum(pc.kept)) <= max(1, int(np.ceil(0.5 * n_tiles)))
    assert bool(jnp.all(~pc.kept | pc.tiles))        # kept ⊆ live
    # same treedef as a plan with a different budget -> no recompile path
    t1 = jax.tree_util.tree_structure(clamped)
    t2 = jax.tree_util.tree_structure(
        MoRExecutionPlan(layer, mode="tiled", tile_m=8, tile_n=64,
                         cap_live=jnp.asarray(0.9, jnp.float32)))
    assert t1 == t2


# -- the rebuilt serve driver ----------------------------------------------

def test_serve_main_engine_report(tmp_path):
    """serve.main on a mixed trace: per-layer skip fractions and the
    calibrated capacities land in the report JSON (file properly
    closed/flushed via the context manager)."""
    from repro.launch.serve import main as serve_main
    out = tmp_path / "serve.json"
    r = serve_main(["--arch", "granite-3-2b", "--reduced", "--batch", "2",
                    "--requests", "4", "--prompt-min", "6",
                    "--prompt-max", "24", "--gen-len", "6",
                    "--mor", "tiled", "--calib-steps", "2",
                    "--calibrate-capacity", "0.9",
                    "--out-json", str(out)])
    import json
    on_disk = json.loads(out.read_text())
    assert on_disk["requests_finished"] == 4
    assert "per_layer_frac_computed" in on_disk
    assert len(on_disk["per_layer_frac_computed"]) == 2   # reduced layers
    assert "per_layer_capacity" in on_disk
    assert on_disk["tokens_per_s"] > 0
    assert r["mor_mode"] == "tiled"


def test_serve_main_shared_prefix_trace(tmp_path):
    """serve.main with --shared-prefix: the prefix-cache counters land
    in the report JSON (hit rate, pages shared, chunks skipped) and the
    trace actually hit."""
    from repro.launch.serve import main as serve_main
    out = tmp_path / "serve_prefix.json"
    r = serve_main(["--arch", "granite-3-2b", "--reduced", "--batch", "2",
                    "--requests", "4", "--prompt-min", "4",
                    "--prompt-max", "8", "--gen-len", "4",
                    "--shared-prefix", "24", "--chunk", "8",
                    "--out-json", str(out)])
    import json
    on_disk = json.loads(out.read_text())
    pc = on_disk["prefix_cache"]
    assert pc["hit_rate"] > 0
    assert pc["chunks_skipped"] > 0
    assert pc["pages_shared"] > 0
    assert r["layout"] == "paged"
    # and the toggle really disables it
    r_cold = serve_main(["--arch", "granite-3-2b", "--reduced",
                         "--batch", "2", "--requests", "4",
                         "--prompt-min", "4", "--prompt-max", "8",
                         "--gen-len", "4", "--shared-prefix", "24",
                         "--chunk", "8", "--no-prefix-cache"])
    assert "prefix_cache" not in r_cold


def test_moe_serve_main_reports_per_expert_capacity(tmp_path):
    """A MoE serve trace with --calibrate-capacity: expert-level MoR runs
    in tiled mode through calibrate_moe, the telemetry bins per-(layer,
    expert) liveness, and the calibrated capacities land in the report
    shaped (L_moe, E)."""
    from repro.launch.serve import main as serve_main
    out = tmp_path / "serve_moe.json"
    r = serve_main(["--arch", "mixtral-8x7b", "--reduced", "--batch", "2",
                    "--requests", "3", "--prompt-min", "4",
                    "--prompt-max", "12", "--gen-len", "4",
                    "--mor", "tiled", "--calib-steps", "2",
                    "--calibrate-capacity", "0.9",
                    "--out-json", str(out)])
    cfg = reduce_config(get_config("mixtral-8x7b"))
    L_moe = cfg.n_layers - cfg.first_k_dense
    assert "moe_mor_stats" in r["per_layer_capacity"]
    caps = np.asarray(r["per_layer_capacity"]["moe_mor_stats"])
    assert caps.shape == (L_moe, cfg.n_experts)
    assert np.all((caps > 0.0) & (caps <= 1.0))
    live = np.asarray(r["per_expert_frac_tiles_live"])
    assert live.shape == (L_moe, cfg.n_experts)
    assert r["requests_finished"] == 3

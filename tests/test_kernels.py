"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; lowering targets TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictor import make_identity_layer
from repro.kernels import ops, ref

RNG = np.random.default_rng(3)

SHAPES = [(8, 128, 128), (16, 256, 384), (48, 200, 300), (128, 512, 256),
          (5, 64, 130)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_binary_dot_sweep(shape, dtype):
    M, K, N = shape
    x = jnp.asarray(RNG.normal(size=(M, K)), dtype)
    w = jnp.asarray(RNG.normal(size=(K, N)), dtype)
    got = ops.binary_dot(x, w)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.binary_dot_ref(x, w)))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_matmul_sweep(shape, dtype):
    M, K, N = shape
    tm, tn = 8, 128
    x = jnp.asarray(RNG.normal(size=(M, K)), dtype)
    w = jnp.asarray(RNG.normal(size=(K, N)), dtype)
    nm, nn = -(-M // tm), -(-N // tn)
    mask = jnp.asarray(RNG.random((nm, nn)) > 0.5)
    got = ops.masked_matmul(x, w, mask, tile_m=tm, tile_n=tn)
    want = ref.masked_matmul_ref(x, w, mask, tm, tn)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


def test_masked_matmul_dead_tiles_exact_zero():
    x = jnp.asarray(RNG.normal(size=(16, 64)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(64, 256)), jnp.float32)
    mask = jnp.zeros((2, 2), bool).at[0, 0].set(True)
    out = np.asarray(ops.masked_matmul(x, w, mask, tile_m=8, tile_n=128))
    assert np.all(out[8:, :] == 0.0)
    assert np.all(out[:, 128:] == 0.0)
    assert np.any(out[:8, :128] != 0.0)


@pytest.mark.parametrize("capacity_frac", [0.25, 0.5, 1.0])
def test_gather_matmul_capacity(capacity_frac):
    M, K, N = 32, 128, 512
    tm, tn = 8, 128
    x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    nm, nn = M // tm, N // tn
    mask = jnp.asarray(RNG.random((nm, nn)) > 0.4)
    cap = max(1, int(capacity_frac * nm * nn))
    got = ops.gather_matmul(x, w, mask, capacity=cap, tile_m=tm, tile_n=tn)
    want = ref.gather_matmul_ref(x, w, mask, tm, tn, cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


def test_gather_and_masked_matmul_liveness_counts():
    """The telemetry-facing count outputs: n_live = mask sum; n_computed
    clamps to the static capacity AND the traced cap_live budget."""
    M, K, N = 32, 128, 512
    tm, tn = 8, 128
    x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    nm, nn = M // tm, N // tn
    mask = jnp.asarray(RNG.random((nm, nn)) > 0.4)
    n_mask = int(np.asarray(mask).sum())
    out, n_live, n_comp = ops.gather_matmul(x, w, mask, tile_m=tm,
                                            tile_n=tn, with_counts=True)
    assert int(n_live) == n_mask and int(n_comp) == n_mask
    # traced per-layer budget clamps the computed count, not the demand
    out2, n_live2, n_comp2 = ops.gather_matmul(
        x, w, mask, tile_m=tm, tile_n=tn,
        capacity_frac_live=jnp.asarray(0.25, jnp.float32),
        with_counts=True)
    assert int(n_live2) == n_mask
    assert int(n_comp2) == min(n_mask, max(1, int(np.ceil(0.25 * nm * nn))))
    out3, n_live3 = ops.masked_matmul(x, w, mask, tile_m=tm, tile_n=tn,
                                      with_counts=True)
    assert int(n_live3) == n_mask
    np.testing.assert_allclose(np.asarray(out), np.asarray(out3),
                               rtol=2e-5, atol=2e-4)


def test_gather_matmul_all_live_fully_dense():
    M, K, N = 16, 64, 256
    x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    mask = jnp.ones((2, 2), bool)
    got = ops.gather_matmul(x, w, mask, capacity=4, tile_m=8, tile_n=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("shape", [(16, 128, 256), (40, 96, 384)])
def test_fused_mor_tile_mask(shape):
    M, K, N = shape
    x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    mor = make_identity_layer(N)
    mor["enable"] = jnp.asarray(RNG.random(N) > 0.3)
    mor["m"] = jnp.asarray(RNG.normal(1, 0.3, N), jnp.float32)
    mor["b"] = jnp.asarray(RNG.normal(0, 2, N), jnp.float32)
    mor["bn_scale"] = jnp.asarray(RNG.gamma(2, 1, N), jnp.float32)
    mor["bn_bias"] = jnp.asarray(RNG.normal(0, 1, N), jnp.float32)
    pn = jnp.asarray(RNG.random((M, N)) > 0.4)
    got = ops.mor_tile_mask(x, w, mor, pn, tile_m=8, tile_n=128)
    want = ref.mor_tile_mask_ref(x, w, mor["m"], mor["b"], mor["bn_scale"],
                                 mor["bn_bias"], mor["enable"], pn, 8, 128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", [(16, 128, 256), (40, 96, 384)])
def test_fused_mor_tile_mask_residual(shape):
    """The 6th coef row: a per-element residual input shifts the fitted
    line inside the fused kernel (matching hybrid_predict's residual
    handling) — kernel-mode masks with residual inputs no longer fall
    back to the jnp predictor."""
    M, K, N = shape
    x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    mor = make_identity_layer(N)
    mor["enable"] = jnp.asarray(RNG.random(N) > 0.3)
    mor["m"] = jnp.asarray(RNG.normal(1, 0.3, N), jnp.float32)
    mor["b"] = jnp.asarray(RNG.normal(0, 2, N), jnp.float32)
    mor["bn_scale"] = jnp.asarray(RNG.gamma(2, 1, N), jnp.float32)
    mor["bn_bias"] = jnp.asarray(RNG.normal(0, 1, N), jnp.float32)
    pn = jnp.asarray(RNG.random((M, N)) > 0.4)
    res = jnp.asarray(RNG.normal(0, 3, (M, N)), jnp.float32)
    got = ops.mor_tile_mask(x, w, mor, pn, residual=res, tile_m=8,
                            tile_n=128)
    want = ref.mor_tile_mask_ref(x, w, mor["m"], mor["b"], mor["bn_scale"],
                                 mor["bn_bias"], mor["enable"], pn, 8, 128,
                                 residual=res)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # a dominating negative residual must kill every tile the proxy +
    # rookie agree on (here: all of them) — proving the input is wired
    mor2 = dict(mor)
    mor2["enable"] = jnp.ones((N,), bool)
    kill = jnp.full((M, N), -1e6, jnp.float32)
    dead = ops.mor_tile_mask(x, w, mor2, jnp.ones((M, N), bool),
                             residual=kill, tile_m=8, tile_n=128)
    assert not np.any(np.asarray(dead))


def test_executor_kernel_mode_residual_uses_fused_predictor(monkeypatch):
    """ROADMAP follow-up closed: mode='kernel' with a residual input must
    route through the fused kernel, never the jnp hybrid_predict."""
    import repro.core.executor as executor
    from repro.core.masked_ffn import mor_relu_matmul
    from repro.core.policy import build_mor_layer
    from repro.configs.base import MoRConfig
    K, N, T = 64, 256, 32
    w = RNG.normal(size=(K, N)).astype(np.float32)
    xs = RNG.normal(size=(T, K)).astype(np.float32)
    m = np.ones(N, np.float32)
    b = np.zeros(N, np.float32)
    c = np.full(N, 0.9, np.float32)
    mor = build_mor_layer(m, b, c, None, MoRConfig(corr_threshold=0.5))
    res = jnp.asarray(RNG.normal(size=(T, N)), jnp.float32)

    def _boom(*a, **k):
        raise AssertionError("jnp hybrid_predict called in kernel mode "
                             "with residual")
    monkeypatch.setattr(executor, "hybrid_predict", _boom)
    y, st = mor_relu_matmul(jnp.asarray(xs), jnp.asarray(w), mor,
                            activation="relu", mode="kernel", residual=res)
    assert np.isfinite(np.asarray(y)).all()
    # tiled oracle agrees on the outputs
    monkeypatch.undo()
    y_t, _ = mor_relu_matmul(jnp.asarray(xs), jnp.asarray(w), mor,
                             activation="relu", mode="tiled", residual=res)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_t),
                               rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("shape", [(16, 128, 256), (32, 512, 384)])
def test_binary_dot_packed(shape):
    """Bit-packed sign weights (8/byte, the binWeight-SRAM analogue)
    reproduce the unpacked binary dot exactly."""
    from repro.kernels.binary_dot_packed import (binary_dot_packed,
                                                 pack_signs, unpack_signs)
    M, K, N = shape
    x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    packed = pack_signs(w)
    assert packed.shape == (K // 8, N) and packed.dtype == jnp.uint8
    # pack/unpack roundtrip
    signs = unpack_signs(packed, K)
    np.testing.assert_array_equal(
        np.asarray(signs), np.where(np.asarray(w) < 0, -1, 1))
    got = binary_dot_packed(x, packed, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.binary_dot_ref(x, w)))

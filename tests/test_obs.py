"""repro.obs tests: the metrics registry (families, labels, histogram
quantiles, Prometheus text), hypothesis property tests on histogram
bucketing, the span tracer (span ordering, TTFT/ITL accounting, Chrome
trace validity), the device-resident metrics block (exact host-mirror
equality, NO extra drains in the hot loop, obs on == off token/dispatch
parity), the kernel-trace scopes, and the telemetry-export layout
dedupe (the slotted+paged double-report fix)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import get_model
from repro.obs import (DeviceMetricsSpec, MetricsRegistry, Observability,
                       Tracer, validate_chrome_trace)
from repro.obs.device import SCALE
from repro.serving import Engine


# -- registry --------------------------------------------------------------

def test_registry_counter_gauge_labels():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests", ("kind",))
    c.inc(kind="a")
    c.inc(2, kind="a")
    c.inc(kind="b")
    assert c.get(kind="a") == 3 and c.get(kind="b") == 1
    c.set(7, kind="b")                     # mirror semantics: idempotent
    c.set(7, kind="b")
    assert c.get(kind="b") == 7
    g = reg.gauge("depth", "queue depth")
    g.set(4)
    snap = reg.snapshot()
    assert snap["req_total"]["type"] == "counter"
    assert {tuple(v["labels"].items()) for v in
            snap["req_total"]["values"]} == {(("kind", "a"),),
                                             (("kind", "b"),)}
    assert snap["depth"]["values"][0]["value"] == 4
    # idempotent re-creation returns the same family; kind mismatch raises
    assert reg.counter("req_total", "requests", ("kind",)) is c
    with pytest.raises(AssertionError):
        reg.gauge("req_total", "nope")


def test_registry_histogram_summary_and_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4
    assert s["min"] == pytest.approx(0.05) and s["max"] == pytest.approx(5.0)
    assert 0.1 <= s["p50"] <= 1.0          # both 0.5s land in (0.1, 1]
    assert s["p99"] <= 5.0                 # clamped to observed max
    # beyond-last-bucket observations land in +Inf but keep exact max
    h.observe(100.0)
    assert h.summary()["max"] == pytest.approx(100.0)


def test_registry_prometheus_text():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "xs", ("k",))
    c.inc(3, k="v")
    h = reg.histogram("d_seconds", "dur", buckets=(1.0, 2.0))
    h.observe(1.5)
    txt = reg.to_prometheus()
    assert '# TYPE x_total counter' in txt
    assert 'x_total{k="v"} 3' in txt
    assert 'd_seconds_bucket{le="2.0"} 1' in txt    # cumulative
    assert 'd_seconds_bucket{le="+Inf"} 1' in txt
    assert 'd_seconds_count 1' in txt


def test_registry_family_clear():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(1.0,))
    h.observe(0.5)
    h.clear()
    assert h.summary()["count"] == 0


# -- histogram bucket invariants (property-tested under hypothesis) --------

def _check_histogram_invariants(values):
    reg = MetricsRegistry()
    edges = (0.001, 0.01, 0.1, 1.0, 10.0, 100.0)
    h = reg.histogram("h", "", buckets=edges)
    for v in values:
        h.observe(v)
    s = h.summary()
    row = next(iter(h.series()))[1]
    # bucket counts partition the observations (last slot = +Inf)
    assert sum(row.counts) == len(values) == s["count"]
    # each bucket count matches the definitional le-partition
    arr = np.asarray(values, np.float64)
    lo = 0.0
    for i, e in enumerate(edges):
        assert row.counts[i] == int(((arr > lo) & (arr <= e)).sum())
        lo = e
    assert row.counts[-1] == int((arr > edges[-1]).sum())
    assert s["sum"] == pytest.approx(float(arr.sum()), rel=1e-6)
    assert s["min"] == pytest.approx(float(arr.min()))
    assert s["max"] == pytest.approx(float(arr.max()))
    # quantiles are monotone and inside the observed range
    qs = [h.quantile(q) for q in (0.1, 0.5, 0.9, 0.99)]
    assert all(a <= b + 1e-12 for a, b in zip(qs, qs[1:]))
    assert all(s["min"] - 1e-12 <= q <= s["max"] + 1e-12 for q in qs)


@pytest.mark.parametrize("values", [
    [0.5], [1e-6, 1e3, 1e3], [0.001, 0.01, 0.1, 1.0, 10.0, 100.0],
    list(np.random.RandomState(0).uniform(1e-6, 200.0, size=64)),
    [150.0, 180.0], [0.0005] * 10 + [50.0] * 3,
])
def test_histogram_bucket_invariants(values):
    _check_histogram_invariants(values)


try:                                      # dev extra; CI installs it
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=1e-6, max_value=1e3,
                              allow_nan=False), min_size=1, max_size=200))
    def test_histogram_bucket_invariants_property(values):
        _check_histogram_invariants(values)
except ModuleNotFoundError:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_histogram_bucket_invariants_property():
        pass


# -- tracer ----------------------------------------------------------------

def _drive_tracer(tr):
    """One scripted request: submit -> mixed dispatch (admit + full
    prefill, emits first token) -> two decode dispatches -> finish."""
    t = [100.0]

    def tick(dt):
        t[0] += dt
        return t[0]

    tr.on_submit(7, t=tick(0.0))
    t0 = tick(0.010)                        # queued 10ms
    tr.on_dispatch("mixed", t0, tick(0.020), admitted=[(0, 7)],
                   prefilling=[(0, 7, 0, 8)], emits=[(0, 7)],
                   finished=[], queue_depth=0, n_active=1)
    for _ in range(2):
        t0 = tick(0.001)
        tr.on_dispatch("decode", t0, tick(0.005), admitted=[],
                       prefilling=[], emits=[(0, 7)], finished=[],
                       queue_depth=0, n_active=1)
    t0 = tick(0.001)
    tr.on_dispatch("decode", t0, tick(0.005), admitted=[], prefilling=[],
                   emits=[(0, 7)], finished=[7], queue_depth=0,
                   n_active=1)


def test_tracer_span_ordering_and_latencies():
    reg = MetricsRegistry()
    tr = Tracer(registry=reg)
    _drive_tracer(tr)
    s = tr.summary()
    assert s["n_requests"] == 1 and s["n_dispatches"] == 4
    # TTFT = submit -> end of the first emitting dispatch = 10 + 20 ms
    assert s["ttft"]["count"] == 1
    assert s["ttft"]["max"] == pytest.approx(0.030, abs=1e-6)
    # ITL between the three emitting-dispatch ends: 6ms each
    assert s["itl"]["count"] == 3
    assert s["itl"]["max"] == pytest.approx(0.006, abs=1e-6)
    assert s["queue_wait"]["max"] == pytest.approx(0.010, abs=1e-6)
    obj = tr.to_chrome_trace()
    assert validate_chrome_trace(obj) == []
    pid_name = {e["pid"]: e["args"]["name"] for e in obj["traceEvents"]
                if e["ph"] == "M" and e["name"] == "process_name"}
    evs = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"].split()[0].split("/")[0],
                           []).append(e)
    # spans nest: queued precedes prefill, prefill precedes decode,
    # the request span covers submit -> finish
    q = by_name["queued"][0]
    pf = by_name["prefill"][0]
    dec = by_name["decode"]
    req = by_name["request"][0]
    assert q["ts"] + q["dur"] <= pf["ts"] + 1
    assert all(pf["ts"] + pf["dur"] <= d["ts"] + 1
               for d in dec[1:])           # decode spans after prefill
    assert req["ts"] <= q["ts"]
    assert req["dur"] >= (pf["ts"] + pf["dur"]) - req["ts"] - 1
    # dispatch spans ride the engine pid, slot spans the slots pid
    assert {pid_name[e["pid"]] for e in by_name["dispatch"]} == {"engine"}
    assert pid_name[pf["pid"]] == "slots"


def test_tracer_reset_clears_histograms():
    reg = MetricsRegistry()
    tr = Tracer(registry=reg)
    _drive_tracer(tr)
    tr.reset()
    assert tr.summary()["n_requests"] == 0
    assert reg.get("repro_serving_ttft_seconds").summary()["count"] == 0
    _drive_tracer(tr)                       # usable after reset
    assert tr.summary()["ttft"]["count"] == 1


def test_trace_validator_flags_malformed():
    assert validate_chrome_trace({"nope": 1})
    assert validate_chrome_trace({"traceEvents": [{"ph": "X"}]})
    assert validate_chrome_trace(
        {"traceEvents": [{"ph": "X", "name": "a", "pid": "p",
                          "ts": 1.0, "dur": -2.0}]})
    assert validate_chrome_trace(
        {"traceEvents": [{"ph": "X", "name": "a", "pid": "p",
                          "ts": 1.0, "dur": 2.0}]}) == []


# -- device metrics block --------------------------------------------------

def test_device_metrics_accumulate_and_read():
    spec = DeviceMetricsSpec({"mor_stats": (3,)})
    blk = spec.init()
    aux = {"mor_stats": {
        "n_tiles": jnp.asarray([10, 10, 10], jnp.int32),
        "tiles_skipped": jnp.asarray([2, 0, 5], jnp.int32),
        "frac_tiles_live": jnp.asarray([0.8, 1.0, 0.5], jnp.float32)}}
    scalars = {"dispatches": 1, "prefill_tokens": 16, "decode_tokens": 0,
               "pages_touched": 4, "kv_page_resets": 2,
               "kv_page_copies": 0, "state_page_resets": 0,
               "state_page_copies": 0}
    for _ in range(2):
        blk = spec.accumulate(blk, scalars, aux)
    out = spec.read(blk)
    assert out["dispatches"] == 2 and out["prefill_tokens"] == 32
    assert out["kv_page_resets"] == 4
    g = out["groups"]["mor_stats"]
    np.testing.assert_array_equal(g["tiles_total"], [20, 20, 20])
    np.testing.assert_array_equal(g["tiles_skipped"], [4, 0, 10])
    np.testing.assert_allclose(g["mean_frac_tiles_live"],
                               [0.8, 1.0, 0.5], atol=1.5 / SCALE)
    # multi-row (sharded) blocks: header from row 0, shard-local summed
    blk2 = spec.init(n_rows=2)
    blk2 = blk2 + jnp.stack([spec.delta(scalars, aux)] * 2)
    out2 = spec.read(blk2)
    assert out2["dispatches"] == 1          # replicated header, row 0
    assert out2["kv_page_resets"] == 4      # shard-local, row-summed


# -- engine integration: parity + no extra drains --------------------------

def _mini_engine(obs=None, layout="paged"):
    cfg = reduce_config(get_config("granite-3-2b"))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, mor_mode="dense", n_slots=2, max_len=96,
                 chunk=8, layout=layout, obs=obs)
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(1, cfg.vocab_size, size=n).astype(np.int32), 5)
            for n in (9, 17, 6)]
    return eng, reqs


def test_engine_obs_on_off_parity_and_single_drain(monkeypatch):
    """The obs stack must not change WHAT the engine computes (tokens,
    dispatch count) and must not add hot-loop device syncs: the metrics
    block is drained host-side exactly once, at the flush boundary."""
    eng_off, reqs = _mini_engine(obs=None)
    out_off = eng_off.run([(p.copy(), g) for p, g in reqs])

    obs = Observability()
    eng_on, _ = _mini_engine(obs=obs)
    calls = {"step": 0, "drain": 0}
    inner_step = eng_on._step

    def counting_step(*a, **kw):
        calls["step"] += 1
        return inner_step(*a, **kw)

    eng_on._step = counting_step
    spec = eng_on._mspec
    assert spec is not None
    inner_read = spec.read

    def counting_read(block):
        calls["drain"] += 1
        return inner_read(block)

    monkeypatch.setattr(spec, "read", counting_read)
    out_on = eng_on.run([(p.copy(), g) for p, g in reqs])

    assert {r: list(map(int, np.asarray(t))) for r, t in out_on.items()} \
        == {r: list(map(int, np.asarray(t))) for r, t in out_off.items()}
    assert calls["step"] == eng_off.counters["dispatches"] \
        == eng_on.counters["dispatches"]
    assert calls["drain"] == 1              # one drain at run()'s flush
    # the device block mirrors the host counters exactly
    dm = eng_on._last_device_metrics
    for k in ("dispatches", "prefill_tokens", "decode_tokens"):
        assert dm[k] == eng_on.counters[k], (k, dm[k], eng_on.counters)


def test_engine_report_obs_sections():
    obs = Observability()
    eng, reqs = _mini_engine(obs=obs)
    eng.run(reqs)
    rep = eng.report()
    assert rep["obs"]["device_metrics"]["dispatches"] == rep["dispatches"]
    t = rep["obs"]["tracing"]
    assert t["n_requests"] == len(reqs)
    assert t["ttft"]["count"] == len(reqs)
    obj = obs.tracer.to_chrome_trace()
    assert validate_chrome_trace(obj) == []
    assert json.loads(json.dumps(rep["obs"])) == rep["obs"]  # JSON-safe
    # registry landed the device counts under the engine families
    reg = obs.registry
    assert reg.get("repro_engine_dispatches_total") \
              .get(layout="paged") == rep["dispatches"]


# -- kernel trace scopes ---------------------------------------------------

def test_kernel_trace_scopes():
    from repro.kernels import paged_attention as pk
    pk.reset_kernel_traces()
    base = pk.kernel_traces()
    assert set(base) == {"gqa", "mla"} and sum(base.values()) == 0
    pk._bump_trace("gqa")
    with pk.trace_scope() as inner:
        pk._bump_trace("gqa")
        pk._bump_trace("mla")
        assert pk.kernel_traces() == {"gqa": 1, "mla": 1}  # innermost
        with pk.trace_scope() as deepest:
            pk._bump_trace("mla")
            assert pk.kernel_traces() == {"gqa": 0, "mla": 1}
        assert deepest == {"gqa": 0, "mla": 1}  # survives scope exit
    assert inner == {"gqa": 1, "mla": 2}
    assert pk.kernel_traces() == {"gqa": 2, "mla": 2}  # root saw all
    pk.reset_kernel_traces()
    assert sum(pk.kernel_traces().values()) == 0


# -- telemetry export: layout dedupe (the double-report fix) ---------------

def test_export_telemetry_layout_dedupe():
    """Slotted + paged engines sharing one registry in one process must
    not double-report: every series is keyed by layout and written with
    idempotent set, so re-export overwrites itself and the two layouts
    coexist as distinct series."""
    from repro.serving.telemetry import ServingTelemetry, export_telemetry
    reg = MetricsRegistry()
    tel = ServingTelemetry()
    tel.update({"mor_stats": {
        "frac_tiles_live": jnp.asarray([0.5, 1.0]),
        "frac_computed": jnp.asarray([0.5, 1.0]),
        "frac_tiles_computed": jnp.asarray([0.5, 1.0])}})
    caps = {"mor_stats": np.asarray([0.6, 0.9])}
    for _ in range(2):                      # re-export: idempotent
        export_telemetry(reg, tel, layout="slotted", capacities=caps)
        export_telemetry(reg, tel, layout="paged", capacities=caps)
    snap = reg.snapshot()
    cap_rows = snap["repro_telemetry_capacity"]["values"]
    by_layout = {}
    for v in cap_rows:
        by_layout.setdefault(v["labels"]["layout"], []).append(v["value"])
    assert set(by_layout) == {"slotted", "paged"}
    # exactly one series per (layout, layer) — no duplicate appends
    assert sorted(by_layout["slotted"]) == [0.6, 0.9]
    assert sorted(by_layout["paged"]) == [0.6, 0.9]

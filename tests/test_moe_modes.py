"""Expert-level MoR differential matrix (ISSUE 3): exact == tiled ==
kernel expert outputs for ``moe_apply`` AND ``moe_apply_a2a``, swept
over (experts, top_k, capacity factor, tile geometry, dtype) including
ragged tails; dispatch/capacity property tests (plain seeded versions —
the hypothesis variants live in test_property_hypothesis.py); and the
dense-mode regression (no predictor work when MoR is off)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core.predictor import (predictor_eval_count,
                                  reset_predictor_eval_count)
from repro.models.layers import moe

RNG = np.random.default_rng(11)


def truth_proxy_layer(f: int, E: int) -> dict:
    """(E,)-stacked MoRLayer whose skips are EXACTLY the true zeros:
    every neuron is its own proxy (evaluated at base precision), the
    binary rookie always votes skip (m=0, b=-1), so skip == true ReLU
    zero.  Predicted-dead neurons then contribute exact zeros in every
    mode, making exact (neuron-granular) == tiled/kernel (tile-granular)
    == dense a hard equality — the differential matrix's oracle."""
    idx = jnp.arange(f, dtype=jnp.int32)
    one = {
        "m": jnp.zeros((f,), jnp.float32),
        "b": jnp.full((f,), -1.0, jnp.float32),
        "enable": jnp.ones((f,), bool),
        "proxy_slot": idx,
        "is_proxy": jnp.zeros((f,), bool),
        "perm": idx,
        "inv_perm": idx,
        "bn_scale": jnp.ones((f,), jnp.float32),
        "bn_bias": jnp.zeros((f,), jnp.float32),
    }
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (E,) + a.shape), one)


def _moe_cfg(E, k, cf, tile_m, tile_n):
    from repro.configs.base import MoRConfig
    cfg = reduce_config(get_config("mixtral-8x7b"))
    return cfg.replace(
        n_experts=E, top_k=k, capacity_factor=cf, n_shared_experts=0,
        mor=MoRConfig(enabled=True, relufied=True, tile_m=tile_m,
                      tile_n=tile_n))


# -- the differential matrix: moe_apply ------------------------------------

@pytest.mark.parametrize("E,k,cf", [(4, 2, 1.25), (8, 2, 4.0), (4, 1, 2.0)])
@pytest.mark.parametrize("tile_m,tile_n", [(8, 128), (4, 16), (8, 32)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_moe_modes_differential(E, k, cf, tile_m, tile_n, dtype):
    """exact == tiled == kernel (== dense, since the truth-proxy layer
    only skips true zeros) over routing/capacity/tile sweeps.  T = 21
    gives ragged capacity buffers (C % tile_m != 0) for every cf."""
    cfg = _moe_cfg(E, k, cf, tile_m, tile_n)
    key = jax.random.PRNGKey(E * 10 + k)
    params = moe.moe_init(key, cfg)
    dt = jnp.dtype(dtype)
    x = jax.random.normal(key, (3, 7, cfg.d_model), jnp.float32).astype(dt)
    f = cfg.moe_d_ff or cfg.d_ff
    em = truth_proxy_layer(f, E)

    y_dense, _ = moe.moe_apply(params, cfg, x)
    tol = dict(rtol=2e-4, atol=2e-3) if dtype == "float32" else \
        dict(rtol=4e-2, atol=8e-2)
    outs = {}
    for mode in ("exact", "tiled", "kernel"):
        y, aux = moe.moe_apply(params, cfg, x, mor={"experts": em},
                               mor_mode=mode)
        outs[mode] = np.asarray(y, np.float32)
        stats = aux["mor_stats"]
        assert np.asarray(stats["frac_tiles_live"]).shape == (E,)
        np.testing.assert_allclose(outs[mode],
                                   np.asarray(y_dense, np.float32),
                                   err_msg=f"{mode} vs dense", **tol)
    # modes agree with each other even tighter than with dense
    np.testing.assert_allclose(outs["tiled"], outs["exact"], **tol)
    np.testing.assert_allclose(outs["kernel"], outs["tiled"], **tol)


def test_moe_modes_differential_with_token_mask():
    """Same equality through the serving path (token_mask + the
    serving-shape-aware lossless capacity)."""
    cfg = _moe_cfg(4, 2, 1.25, 4, 16)
    key = jax.random.PRNGKey(3)
    params = moe.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 9, cfg.d_model), jnp.float32)
    tm = jnp.asarray(np.array([[True] * 9, [True] * 5 + [False] * 4]))
    f = cfg.moe_d_ff or cfg.d_ff
    em = truth_proxy_layer(f, 4)
    y_dense, _ = moe.moe_apply(params, cfg, x, token_mask=tm)
    for mode in ("exact", "tiled", "kernel"):
        y, _ = moe.moe_apply(params, cfg, x, mor={"experts": em},
                             mor_mode=mode, token_mask=tm)
        valid = np.asarray(tm)[..., None]
        np.testing.assert_allclose(
            np.asarray(y) * valid, np.asarray(y_dense) * valid,
            rtol=2e-4, atol=2e-3, err_msg=mode)


# -- the differential matrix: moe_apply_a2a (EP shard_map) ------------------

_A2A_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduce_config
from repro.configs.base import MoRConfig
from repro.distributed.sharding_rules import activation_context
from repro.models.layers import moe

E, k = 4, 2
cfg = reduce_config(get_config("mixtral-8x7b")).replace(
    n_experts=E, top_k=k, n_shared_experts=0,
    capacity_factor=float(E) / k,          # lossless: local == global
    expert_sharding="ep_shmap",
    mor=MoRConfig(enabled=True, relufied=True, tile_m=4, tile_n=16))
key = jax.random.PRNGKey(0)
params = moe.moe_init(key, cfg)
f = cfg.moe_d_ff or cfg.d_ff
idx = jnp.arange(f, dtype=jnp.int32)
one = {"m": jnp.zeros((f,), jnp.float32),
       "b": jnp.full((f,), -1.0, jnp.float32),
       "enable": jnp.ones((f,), bool),
       "proxy_slot": idx, "is_proxy": jnp.zeros((f,), bool),
       "perm": idx, "inv_perm": idx,
       "bn_scale": jnp.ones((f,), jnp.float32),
       "bn_bias": jnp.zeros((f,), jnp.float32)}
em = jax.tree_util.tree_map(
    lambda a: jnp.broadcast_to(a[None], (E,) + a.shape), one)
# tokens divisible by dp * MP on a (data=4, model=2) mesh
x = jax.random.normal(key, (8, 4, cfg.d_model), jnp.float32)
mesh = jax.make_mesh((4, 2), ("data", "model"))
with activation_context(mesh):
    y_dense, _ = moe.moe_apply_a2a(params, cfg, x)
    assert y_dense is not None
    for mode in ("exact", "tiled", "kernel"):
        y, _ = moe.moe_apply_a2a(params, cfg, x, mor={"experts": em},
                                 mor_mode=mode)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_dense),
                                   rtol=2e-4, atol=2e-3, err_msg=mode)
# single-chip reference: same math without the mesh
y_ref, _ = moe.moe_apply(params, cfg.replace(expert_sharding="tp"), x)
np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-3)
# an attached plan's calibrated per-expert cap_live budget must engage
# on the a2a path too (sliced over the expert axis like the weights)
from repro.core.executor import MoRExecutionPlan
capped = MoRExecutionPlan(em, mode="tiled", tile_m=4, tile_n=16,
                          cap_live=jnp.full((E,), 0.05, jnp.float32))
with activation_context(mesh):
    y_cap, _ = moe.moe_apply_a2a(params, cfg, x, mor={"experts": capped})
assert np.isfinite(np.asarray(y_cap)).all()
assert float(np.abs(np.asarray(y_cap) - np.asarray(y_dense)).max()) > 1e-4, \
    "cap_live budget did not engage on the a2a path"
print("A2A_MODES_OK")
"""


def test_moe_a2a_modes_differential():
    """Expert slicing (EP shard_map): exact == tiled == kernel with
    expert-MoR leaves sliced over the model axis, and the sharded result
    matches the single-chip moe_apply."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _A2A_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.getcwd())
    assert r.returncode == 0, r.stderr[-3000:]
    assert "A2A_MODES_OK" in r.stdout


# -- dense-mode regression: MoR off must mean NO predictor work -------------

def test_moe_dense_mode_runs_no_predictor():
    """mor_mode="dense" (MoR off) must skip predictor work entirely in
    MoE — the old code built exact-mode plans regardless of the
    requested mode.  Also: an attached plan whose own mode is "dense"
    stays off even under a non-dense mor_mode argument."""
    from repro.core.executor import MoRExecutionPlan
    cfg = _moe_cfg(4, 2, 1.25, 8, 128)
    key = jax.random.PRNGKey(1)
    params = moe.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 6, cfg.d_model), jnp.float32)
    f = cfg.moe_d_ff or cfg.d_ff
    em = truth_proxy_layer(f, 4)

    reset_predictor_eval_count()
    y, aux = moe.moe_apply(params, cfg, x, mor={"experts": em},
                           mor_mode="dense")
    assert predictor_eval_count() == 0
    assert "mor_stats" not in aux
    # attached plan with mode="dense" is authoritative (never re-armed)
    plan = MoRExecutionPlan(em, mode="dense")
    y2, aux2 = moe.moe_apply(params, cfg, x,
                             mor={"experts": plan}, mor_mode="tiled")
    assert predictor_eval_count() == 0
    assert "mor_stats" not in aux2
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y))
    # and a live mode runs the predictor EXACTLY once per layer call
    moe.moe_apply(params, cfg, x, mor={"experts": em}, mor_mode="tiled")
    assert predictor_eval_count() == 1


# -- per-expert capacity clamps --------------------------------------------

def test_expert_cap_live_clamps_per_expert():
    """Per-expert traced cap_live budgets clamp each expert's realised
    tile compute independently (the attach path for calibrated
    per-(layer, expert) capacities)."""
    from repro.core.executor import MoRExecutionPlan
    E, C, d, f = 3, 16, 64, 256
    rng = np.random.default_rng(5)
    eb = jnp.asarray(rng.normal(size=(E, C, d)), jnp.float32)
    wu = jnp.asarray(rng.normal(size=(E, d, f)), jnp.float32)
    wd = jnp.asarray(rng.normal(size=(E, f, d)), jnp.float32)
    # everything predicted live (enable off -> no skips) so the clamp is
    # the only thing cutting compute
    idx = jnp.arange(f, dtype=jnp.int32)
    one = {"m": jnp.ones((f,), jnp.float32),
           "b": jnp.zeros((f,), jnp.float32),
           "enable": jnp.zeros((f,), bool),
           "proxy_slot": jnp.full((f,), -1, jnp.int32),
           "is_proxy": jnp.zeros((f,), bool), "perm": idx, "inv_perm": idx,
           "bn_scale": jnp.ones((f,), jnp.float32),
           "bn_bias": jnp.zeros((f,), jnp.float32)}
    em = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (E,) + a.shape), one)
    caps = jnp.asarray([0.25, 0.5, 1.0], jnp.float32)
    for mode in ("tiled", "kernel"):
        plan = MoRExecutionPlan(em, mode=mode, tile_m=8, tile_n=64,
                                cap_live=caps)
        _, stats = plan.expert_ffn(eb, wu, wd, activation="relu")
        comp = np.asarray(stats["frac_tiles_computed"])
        n_tiles = (C // 8) * (f // 64)
        for e in range(E):
            budget = np.ceil(float(caps[e]) * n_tiles) / n_tiles
            assert comp[e] <= budget + 1e-6, (mode, e, comp[e], budget)
        # tighter budget -> no more compute than the looser one
        assert comp[0] <= comp[1] + 1e-6 <= comp[2] + 2e-6


def test_gather_matmul_cap_counts_and_zeroes():
    """The kernel's count outputs never exceed cap_live, and rows of
    tiles beyond the clamp are exact zeros (plain seeded version of the
    hypothesis property; oracle = ref.gather_matmul_cap_ref)."""
    from repro.kernels import ops as kops
    from repro.kernels.ref import gather_matmul_cap_ref
    rng = np.random.default_rng(9)
    for trial in range(6):
        nm, nn = int(rng.integers(1, 5)), int(rng.integers(1, 5))
        tm, tn = 8, 16
        M, K, N = nm * tm, 32, nn * tn
        x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
        mask = jnp.asarray(rng.random((nm, nn)) > 0.4)
        cap_frac = float(rng.uniform(0.2, 1.0))
        cap_live = float(rng.uniform(0.1, 1.0))
        out, n_live, n_comp = kops.gather_matmul(
            x, w, mask, capacity_frac=cap_frac,
            capacity_frac_live=cap_live, tile_m=tm, tile_n=tn,
            with_counts=True)
        n_tiles = nm * nn
        cap = max(1, int(cap_frac * n_tiles))
        cl = max(1, int(np.ceil(cap_live * n_tiles)))
        assert int(n_live) == int(np.asarray(mask).sum())
        assert int(n_comp) <= min(cap, cl, int(n_live))
        want = gather_matmul_cap_ref(x, w, mask, tm, tn, capacity=cap,
                                     cap_live=cl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-4, atol=2e-3)
        # non-kept tiles are EXACT zeros
        flat = np.asarray(mask).reshape(-1)
        kept = flat & (np.cumsum(flat) - 1 < min(cap, cl))
        for t in range(n_tiles):
            i, j = t // nn, t % nn
            tile = np.asarray(out)[i * tm:(i + 1) * tm,
                                   j * tn:(j + 1) * tn]
            if not kept[t]:
                assert np.all(tile == 0.0)


# -- _dispatch_indices properties (plain seeded; hypothesis twin in
#    test_property_hypothesis.py) -------------------------------------------

def _check_dispatch(top_idx: np.ndarray, E: int, C: int):
    slot = np.asarray(moe._dispatch_indices(jnp.asarray(top_idx), E, C))
    T, k = top_idx.shape
    per_expert_slots = {}
    for t in range(T):
        for kk in range(k):
            e = top_idx[t, kk]
            s = slot[t, kk]
            if e >= E:                       # sentinel (masked token)
                assert s == E * C
                continue
            if s < E * C:
                # kept: lands in its own expert's buffer, exactly once
                assert s // C == e
                per_expert_slots.setdefault(e, set())
                assert s % C not in per_expert_slots[e], "slot reused"
                per_expert_slots[e].add(s % C)
    counts = np.bincount(top_idx[top_idx < E].reshape(-1), minlength=E)
    for e in range(E):
        kept = len(per_expert_slots.get(e, ()))
        # drops happen ONLY on capacity overflow, and earlier tokens win
        assert kept == min(counts[e], C)
        dropped = [(t, kk) for t in range(T) for kk in range(k)
                   if top_idx[t, kk] == e and slot[t, kk] == E * C]
        if dropped:
            assert counts[e] > C
            first_drop_t = min(t for t, _ in dropped)
            kept_ts = [t for t in range(T) for kk in range(k)
                       if top_idx[t, kk] == e and slot[t, kk] < E * C]
            assert all(t <= first_drop_t for t in kept_ts)


def test_dispatch_indices_properties():
    for trial in range(20):
        rng = np.random.default_rng(trial)
        E = int(rng.integers(1, 9))
        k = int(rng.integers(1, min(E, 4) + 1))
        T = int(rng.integers(1, 33))
        C = int(rng.integers(1, 2 * T + 1))
        top = np.stack([rng.choice(E, size=k, replace=False)
                        for _ in range(T)]).astype(np.int32)
        if trial % 3 == 0:       # masked-token sentinel rows
            top[rng.random(T) < 0.3] = E
        _check_dispatch(top, E, C)

"""SLO layer: admission policies, page-spill preemption, open-loop
load, and the hardened submit/admission paths (ISSUE 8).

Host-side pieces (policy ordering, scheduler preempt/resume accounting,
the prefill-budget knob, loadgen determinism) test with no device in
the loop.  Engine tests run reduced configs and check the properties
the SLO benchmark's headline rests on: typed rejection never kills the
engine, preemption is token-lossless (spill/restore round-trips both
KV pages and recurrent state), and pool-exhaustion mid-plan recovers
by spilling a victim and rebuilding the batch.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import get_model
from repro.obs import Observability
from repro.serving import (Engine, PriorityPolicy, RequestRejected,
                           get_policy, kv_pool)
from repro.serving.loadgen import (latency_stats, poisson_trace,
                                   run_open_loop)
from repro.serving.scheduler import DECODE, FREE, Request, Scheduler


def _engine(arch="granite-3-2b", n_slots=2, max_len=48, chunk=8,
            **kw):
    cfg = reduce_config(get_config(arch)).replace(serve_chunk=chunk)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return Engine(cfg, params, n_slots=n_slots, max_len=max_len,
                  chunk=chunk, telemetry=False, **kw), cfg


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
            for n in sizes]


# -- satellite: typed rejection replaces bare asserts ----------------------

def test_submit_rejects_typed_and_counts():
    """Every unservable request raises ``RequestRejected`` with a
    machine-readable reason BEFORE entering the queue, the engine
    counts it (obs mirror included), and keeps serving afterwards."""
    eng, cfg = _engine(obs=Observability(device_metrics=False))
    for prompt, max_new, reason in [
            (np.zeros((0,), np.int32), 4, "empty_prompt"),
            (np.arange(1, 5, dtype=np.int32), 0,
             "nonpositive_max_new_tokens"),
            (np.arange(1, 5, dtype=np.int32), -3,
             "nonpositive_max_new_tokens"),
            (np.ones((eng.max_len,), np.int32), 4, "oversize")]:
        with pytest.raises(RequestRejected) as ei:
            eng.submit(prompt, max_new)
        assert ei.value.reason == reason
    assert eng.counters["requests_rejected"] == 4
    assert eng.rejections == {"empty_prompt": 1, "oversize": 1,
                              "nonpositive_max_new_tokens": 2}
    assert not eng.scheduler.has_work, "rejected request entered queue"
    # the engine is still alive: a good request serves to completion
    rid = eng.submit(_prompts(cfg, [6])[0], 3)
    out = eng.run()
    assert len(out[rid]) == 3
    fam = eng.obs.registry.snapshot()["repro_requests_rejected_total"]
    got = {s["labels"]["reason"]: s["value"] for s in fam["values"]}
    assert got == {"empty_prompt": 1.0, "oversize": 1.0,
                   "nonpositive_max_new_tokens": 2.0}


# -- satellite: wall_s is monotonic (perf_counter, not time.time) ----------

def test_wall_clock_uses_perf_counter(monkeypatch):
    """``time.time`` jumping (NTP step, clock slew) must not corrupt
    ``wall_s``: freeze it to a constant — if the engine still measured
    with it, wall_s would come out zero (or negative under a backwards
    step, which this regression originally produced)."""
    eng, cfg = _engine()
    monkeypatch.setattr(time, "time", lambda: 1.0e9)
    eng.submit(_prompts(cfg, [6])[0], 3)
    eng.run()
    assert eng.counters["wall_s"] > 0.0


# -- policy / scheduler units ----------------------------------------------

def _mk_sched(policy, n_slots=2, chunk=4):
    return Scheduler(n_slots, chunk, policy=policy)


def test_priority_policy_orders_and_breaks_ties_by_arrival():
    sched = _mk_sched(get_policy("priority"))
    for rid, pri in [(0, 0), (1, 5), (2, 0), (3, 5)]:
        sched.add(Request(rid, np.arange(1, 5, dtype=np.int32),
                          4, priority=pri))
    sched.policy.order(sched.waiting)
    assert [e.req.rid for e in sched.waiting] == [1, 3, 0, 2]


def test_sjf_policy_orders_by_remaining_prefill():
    sched = _mk_sched(get_policy("sjf"))
    for rid, plen in [(0, 12), (1, 4), (2, 8)]:
        sched.add(Request(rid, np.arange(1, plen + 1, dtype=np.int32), 4))
    sched.policy.order(sched.waiting)
    assert [e.req.rid for e in sched.waiting] == [1, 2, 0]
    # a preempted resume (zero remaining prefill) sorts to the front
    sched.admit()
    sched.preempt(0)
    sched.policy.order(sched.waiting)
    head = sched.waiting[0]
    assert head.resume and head.req.rid == 1


def test_priority_preemption_is_strict_inequality():
    """Equal priorities never preempt each other (no ping-pong); a
    strictly higher class picks the lowest-priority running slot."""
    pol = PriorityPolicy()
    sched = _mk_sched(pol)
    sched.add(Request(0, np.arange(1, 5, dtype=np.int32), 4, priority=1))
    sched.add(Request(1, np.arange(1, 5, dtype=np.int32), 4, priority=2))
    sched.admit()
    from repro.serving.scheduler import PendingEntry
    eq = PendingEntry(Request(2, np.arange(1, 3, dtype=np.int32), 4,
                              priority=1))
    hi = PendingEntry(Request(3, np.arange(1, 3, dtype=np.int32), 4,
                              priority=3))
    assert pol.select_victim(sched.slots, eq) is None
    # admit() ordered by priority, so slot 1 holds the pri-1 request —
    # the strictly-higher entry picks the LOWEST running class
    assert pol.select_victim(sched.slots, hi) == 1


def test_spill_victim_respects_exclude_and_prefers_low_priority():
    pol = get_policy("fcfs")
    sched = _mk_sched(pol)
    sched.add(Request(0, np.arange(1, 5, dtype=np.int32), 4, priority=0))
    sched.add(Request(1, np.arange(1, 5, dtype=np.int32), 4, priority=5))
    sched.admit()
    assert pol.spill_victim(sched.slots) == 0           # low class spills
    assert pol.spill_victim(sched.slots, exclude=[0]) == 1
    assert pol.spill_victim(sched.slots, exclude=[0, 1]) is None


def test_scheduler_preempt_requeues_exact_progress():
    sched = _mk_sched(get_policy("fcfs"), n_slots=1, chunk=4)
    sched.add(Request(0, np.arange(1, 11, dtype=np.int32), 4))
    sched.admit()
    sched.feed(np.array([4]))                           # one chunk done
    sched.preempt(0)
    e = sched.waiting[0]
    assert e.resume and e.offset == 4 and e.n_generated == 0
    assert sched.slots[0].state is FREE
    # re-admission resumes at the recorded offset (place returns it)
    sched.admit(place=lambda s, entry: entry.offset)
    assert sched.slots[0].offset == 4
    # a fully-prefilled resume re-enters DECODE, not PREFILL
    sched.feed(np.array([4]))
    sched.feed(np.array([2]))
    assert sched.slots[0].state is DECODE
    sched.preempt(0)
    sched.admit(place=lambda s, entry: entry.offset)
    assert sched.slots[0].state is DECODE
    assert sched.slots[0].n_generated == 1


def test_prefill_budget_caps_mixed_dispatch():
    """``prefill_budget`` caps the TOTAL prompt tokens per mixed
    dispatch; the first prefilling slot always gets >= 1 token so
    prefill can never starve."""
    sched = _mk_sched(get_policy("fcfs", prefill_budget=5), n_slots=3,
                      chunk=4)
    for rid in range(3):
        sched.add(Request(rid, np.arange(1, 13, dtype=np.int32), 4))
    sched.admit()
    tokens, n_valid, _, _, _, prefilling = sched.build_batch("mixed")
    assert sum(t for _, _, t in prefilling) == 5
    assert [int(n_valid[s]) for s in range(3)] == [4, 1, 0]
    # budget below one chunk still moves: the head slot gets >= 1
    sched.policy.prefill_budget = 0
    tokens, n_valid, *_ = sched.build_batch("mixed")
    assert int(n_valid.sum()) == 12                     # knob off = full


# -- loadgen ----------------------------------------------------------------

def test_poisson_trace_is_seed_deterministic():
    kw = dict(rate=40.0, duration_s=2.0, vocab_size=128, seed=7,
              hi_pri_frac=0.3, oversize_frac=0.1, max_len=64)
    a, b = poisson_trace(**kw), poisson_trace(**kw)
    assert len(a) == len(b) > 0
    for x, y in zip(a, b):
        assert x.t == y.t and x.max_new_tokens == y.max_new_tokens
        assert x.priority == y.priority
        np.testing.assert_array_equal(x.prompt, y.prompt)
    c = poisson_trace(**{**kw, "seed": 8})
    assert [x.t for x in a] != [x.t for x in c]
    assert any(x.priority == 5 for x in a)
    assert any(len(x.prompt) == 64 for x in a), "no oversize injected"


def test_open_loop_records_rejections_and_loses_nothing():
    """Oversize injections are rejected and RECORDED; everything
    submitted finishes with exactly its requested token count."""
    eng, cfg = _engine(n_slots=2, max_len=32)
    arr = poisson_trace(rate=60.0, duration_s=0.6,
                        vocab_size=cfg.vocab_size, seed=3,
                        prompt_len=(4, 12), max_new=(2, 4),
                        oversize_frac=0.25, max_len=32)
    res = run_open_loop(eng, arr)
    assert res.rejected and all(r == "oversize" for _, r in res.rejected)
    assert eng.rejections.get("oversize") == len(res.rejected)
    assert res.n_submitted + len(res.rejected) == len(arr)
    lost = [rid for rid, i in res.submitted.items()
            if len(eng.results.get(rid, []))
            != arr[i].max_new_tokens]
    assert lost == []


def test_latency_stats_splits_priority_classes():
    spans = {0: {"ttft_s": 0.1}, 1: {"ttft_s": 0.3},
             2: {"ttft_s": None}}
    from repro.serving.loadgen import Arrival
    arr = [Arrival(0.0, np.ones(2, np.int32), 2, 0),
           Arrival(0.1, np.ones(2, np.int32), 2, 5),
           Arrival(0.2, np.ones(2, np.int32), 2, 0)]
    st = latency_stats(spans, {0: 0, 1: 1, 2: 2}, arr)
    assert st["all"]["n"] == 2 and st["pri5"]["n"] == 1
    assert st["pri5"]["p50"] == pytest.approx(0.3)


# -- engine: preemption is token-lossless ----------------------------------

@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-3b"])
def test_preemption_token_identity(arch):
    """Force a mid-flight spill + restore and compare against an
    untouched twin engine on the SAME prompts: greedy outputs must be
    bit-identical and the allocator invariants must hold with the
    spill records counted as external refs.  Covers both cache families
    (attention KV pages; rwkv recurrent state pages)."""
    prompts_sizes = [10, 14, 7]
    eng, cfg = _engine(arch, n_slots=2, max_len=48)
    ref, _ = _engine(arch, n_slots=2, max_len=48)
    prompts = _prompts(cfg, prompts_sizes, seed=4)
    want = ref.run([(p, 5) for p in prompts])

    rids = [eng.submit(p, 5) for p in prompts]
    eng.step()
    eng.step()
    victim = eng.policy.spill_victim(eng.scheduler.slots)
    eng._preempt(victim)
    assert eng.counters["preemptions"] == 1
    assert eng.pool.spill_events["spills"] == 1
    if eng.pool.has_kv:
        eng.pool.kv.check(eng.pool.external_refs("kv"))
    if eng.pool.has_state:
        eng.pool.st.check(eng.pool.external_refs("state"))
    while eng.scheduler.has_work:
        eng.step()
    eng.drain()
    assert eng.pool.spill_events["restores"] == 1
    assert not eng._spilled, "spill record leaked"
    for rid, (_, toks) in zip(rids, sorted(want.items())):
        assert eng.results[rid] == toks, "preemption changed tokens"
    if eng.pool.has_kv:
        eng.pool.kv.check(eng.pool.external_refs("kv"))
    if eng.pool.has_state:
        eng.pool.st.check(eng.pool.external_refs("state"))


def test_priority_policy_preempts_and_no_tokens_lost():
    """A high-priority arrival preempts a running low-priority slot
    (spill), the victim resumes later, and EVERY request still emits
    exactly its requested token count."""
    eng, cfg = _engine(n_slots=2, max_len=48, policy="priority")
    prompts = _prompts(cfg, [10, 12, 8], seed=2)
    r_lo = [eng.submit(p, 6, priority=0) for p in prompts[:2]]
    eng.step()
    eng.step()
    r_hi = eng.submit(prompts[2], 6, priority=5)
    while eng.scheduler.has_work:
        eng.step()
    eng.drain()
    assert eng.counters["preemptions"] >= 1
    for rid in r_lo + [r_hi]:
        assert len(eng.results[rid]) == 6
    sm = eng.pool.report()
    assert sm["spill_restores"] == sm["spill_spills"]


def test_plan_writes_exhaustion_spills_and_rebuilds(monkeypatch):
    """Pool exhaustion mid-``plan_writes`` must not kill the step: the
    engine spills a victim, REBUILDS the batch (the victim may be in
    it) and completes every request losslessly."""
    eng, cfg = _engine(n_slots=2, max_len=48)
    real = eng.pool.plan_writes
    calls = {"n": 0}

    def flaky(n_valid):
        calls["n"] += 1
        # fail on the SECOND dispatch: by then no slot was freshly
        # admitted this step, so the spill-victim fallback may fire
        # (freshly admitted slots are protected from spilling)
        if calls["n"] == 2:
            raise kv_pool.PoolExhausted("injected")
        return real(n_valid)

    monkeypatch.setattr(eng.pool, "plan_writes", flaky)
    prompts = _prompts(cfg, [10, 12], seed=6)
    out = eng.run([(p, 5) for p in prompts])
    assert eng.counters["preemptions"] == 1
    assert all(len(t) == 5 for t in out.values())
    eng.pool.kv.check(eng.pool.external_refs("kv"))

"""Angle-based clustering tests (paper §3.2.2)."""
import numpy as np
import pytest

from repro.core.clustering import (closest_neighbor_graph, cluster_layer,
                                   greedy_proxy_clustering,
                                   montecarlo_sign_agreement,
                                   pairwise_cosines)
from repro.core.policy import build_permutation

RNG = np.random.default_rng(1)


def test_sign_disagreement_probability_matches_theory():
    """Paper Eq. 3-4: P[sign(C.A) != sign(C.B)] = theta/180, any dim."""
    for dim in (2, 16, 256):
        for theta in (10.0, 45.0, 90.0, 150.0):
            p = montecarlo_sign_agreement(theta, dim, 200_000)
            assert abs(p - theta / 180.0) < 0.01, (dim, theta, p)


def test_pairwise_cosines_blocked_equals_direct():
    w = RNG.normal(size=(40, 70)).astype(np.float32)
    got = pairwise_cosines(w, block=16)
    wn = w / np.linalg.norm(w, axis=0, keepdims=True)
    np.testing.assert_allclose(got, wn.T @ wn, atol=1e-5)


def test_closest_neighbor_graph_finds_planted_pairs():
    # plant pairs of nearly-parallel vectors
    base = RNG.normal(size=(64, 10))
    cols = []
    for j in range(10):
        cols.append(base[:, j])
        cols.append(base[:, j] + 0.01 * RNG.normal(size=64))
    w = np.stack(cols, 1)
    nn, ang = closest_neighbor_graph(w)
    for j in range(10):
        assert nn[2 * j] == 2 * j + 1
        assert nn[2 * j + 1] == 2 * j
        assert ang[2 * j] < 5.0


def test_closest_neighbor_angle_threshold():
    w = np.eye(8).astype(np.float32)  # all mutually perpendicular
    nn, ang = closest_neighbor_graph(w, max_angle_deg=80.0)
    # nothing within 80 degrees -> everyone self-loops (unclustered)
    np.testing.assert_array_equal(nn, np.arange(8))


def test_greedy_proxy_clustering_invariants():
    w = RNG.normal(size=(32, 100)).astype(np.float32)
    # duplicate some columns so clusters exist
    w[:, 50:] = w[:, :50] + 0.05 * RNG.normal(size=(32, 50))
    cl = cluster_layer(w, max_angle_deg=89.0)
    proxy_of, is_proxy = cl["proxy_of"], cl["is_proxy"]
    # every neuron's proxy is a proxy; proxies are their own proxy
    assert is_proxy[proxy_of].all()
    assert (proxy_of[is_proxy] == np.where(is_proxy)[0]).all()
    # members point at proxies only (no chains, paper's concern)
    members = ~is_proxy
    assert (~members[proxy_of[members]]).all()
    assert cl["n_proxies"] >= 1


def test_indegree_priority():
    """Node with highest indegree becomes a proxy first (paper's order)."""
    # star: nodes 1..4 all point at 0; node 5 points at 1
    nn_idx = np.array([1, 0, 0, 0, 0, 1])
    proxy_of, is_proxy = greedy_proxy_clustering(nn_idx)
    assert is_proxy[0]
    # 1..4 join cluster 0; 5 is left alone -> becomes its own proxy
    assert all(proxy_of[j] == 0 for j in (1, 2, 3, 4))
    assert proxy_of[5] == 5 and is_proxy[5]


def test_build_permutation_is_valid_and_groups_members():
    w = RNG.normal(size=(16, 40)).astype(np.float32)
    w[:, 20:] = w[:, :20] + 0.02 * RNG.normal(size=(16, 20))
    cl = cluster_layer(w)
    perm = build_permutation(cl["proxy_of"], cl["is_proxy"])
    assert sorted(perm) == list(range(40))
    # proxies occupy the leading slots
    n_p = cl["n_proxies"]
    assert cl["is_proxy"][perm[:n_p]].all()
    assert not cl["is_proxy"][perm[n_p:]].any()
